//! Layer-2 cross-file contract rules.
//!
//! EdgeFLow's resume-bit-identity and export-schema guarantees are
//! *cross-file* invariants: a struct defined here must round-trip
//! through an encoder/decoder pair defined there.  The local rules
//! cannot see that, so these passes consume the item index
//! ([`crate::items`]) across every analyzed file:
//!
//! * **checkpoint-parity** — every field of the checkpointed session
//!   types (and every named field of the strategy/schedule cursor
//!   enums) must appear in both its encode and its decode fn body.  A
//!   field added but not serialized is exactly the bug that breaks
//!   resume bit-identity.
//! * **csv-schema-parity** — `METRICS_CSV_HEADER`'s columns must
//!   match `RoundRecord`'s fields in count, membership and order, and
//!   every field must be referenced by `csv_fields` in header order.
//! * **config-surface-parity** — every `ExperimentConfig` field needs
//!   a JSON emit, a JSON parse arm and a CLI override arm (or a
//!   `lint:allow(config-surface-parity): reason` pragma on the field);
//!   every `CampaignSpec` field needs the JSON emit + parse pair.
//!
//! Field matching is by word-boundary token over the masked code view
//! *and* the string-literal view, so both `self.deadline_s` and the
//! serialized key `"deadline_s"` count.  Same-named fields of sibling
//! enum variants alias under this scheme — the check errs lenient
//! there, never noisy.
//!
//! Contract anchors are data ([`DEFAULT_PARITY`] etc.); a missing
//! anchor *type/fn* in a present file is a violation (renames must
//! update the table), while a missing anchor *file* skips the
//! contract (explicit-PATH scans never reach these passes at all —
//! see [`crate::lint_paths`]).

use crate::rules::{count_word, FileAnalysis};
use crate::Rule;

/// Whether a parity target is a struct or an enum (whose struct-like
/// variants' named fields are all checked).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetKind {
    Struct,
    Enum,
}

/// A function anchor: `name` in `file`, optionally constrained to an
/// `impl owner` block.
#[derive(Clone, Copy, Debug)]
pub struct FnRef {
    pub file: &'static str,
    pub name: &'static str,
    pub owner: Option<&'static str>,
}

impl FnRef {
    fn describe(&self) -> String {
        match self.owner {
            Some(o) => format!("{}::{} ({})", o, self.name, self.file),
            None => format!("{} ({})", self.name, self.file),
        }
    }
}

/// One checkpoint-parity contract: `type_name` defined in `def_file`
/// must have every (variant) field appear in both `encode` and
/// `decode` bodies.
#[derive(Clone, Copy, Debug)]
pub struct ParityContract {
    pub type_name: &'static str,
    pub kind: TargetKind,
    pub def_file: &'static str,
    pub encode: FnRef,
    pub decode: FnRef,
}

/// The csv-schema-parity contract: `header_const` and `record` (with
/// its `row_fn` encoder) all live in `file`.
#[derive(Clone, Copy, Debug)]
pub struct CsvContract {
    pub record: &'static str,
    pub file: &'static str,
    pub header_const: &'static str,
    pub row_fn: FnRef,
}

/// The config-surface-parity contract: every field of `type_name`
/// (defined in `def_file`) must appear in each surface fn.
#[derive(Clone, Debug)]
pub struct ConfigContract {
    pub type_name: &'static str,
    pub def_file: &'static str,
    pub surfaces: &'static [(FnRef, &'static str)],
}

const RUNNER: &str = "rust/src/fl/runner.rs";
const METRICS: &str = "rust/src/metrics/mod.rs";

/// The checkpointed session state: everything [`Runner::checkpoint`]
/// persists, straight from PR 3's resume-bit-identity contract.
pub const DEFAULT_PARITY: [ParityContract; 7] = [
    ParityContract {
        type_name: "RunnerCheckpoint",
        kind: TargetKind::Struct,
        def_file: RUNNER,
        encode: FnRef { file: RUNNER, name: "to_json", owner: Some("RunnerCheckpoint") },
        decode: FnRef { file: RUNNER, name: "from_json", owner: Some("RunnerCheckpoint") },
    },
    ParityContract {
        type_name: "DeferredBlob",
        kind: TargetKind::Struct,
        def_file: RUNNER,
        encode: FnRef { file: RUNNER, name: "to_json", owner: Some("RunnerCheckpoint") },
        decode: FnRef { file: RUNNER, name: "from_json", owner: Some("RunnerCheckpoint") },
    },
    // NetSimState serializes inside the runner checkpoint's "net"
    // object, not next to its own definition — exactly the cross-file
    // drift surface this rule exists for.
    ParityContract {
        type_name: "NetSimState",
        kind: TargetKind::Struct,
        def_file: "rust/src/netsim/sim.rs",
        encode: FnRef { file: RUNNER, name: "to_json", owner: Some("RunnerCheckpoint") },
        decode: FnRef { file: RUNNER, name: "from_json", owner: Some("RunnerCheckpoint") },
    },
    ParityContract {
        type_name: "RngState",
        kind: TargetKind::Struct,
        def_file: "rust/src/rng/mod.rs",
        encode: FnRef { file: "rust/src/rng/mod.rs", name: "to_json", owner: Some("RngState") },
        decode: FnRef { file: "rust/src/rng/mod.rs", name: "from_json", owner: Some("RngState") },
    },
    ParityContract {
        type_name: "RoundRecord",
        kind: TargetKind::Struct,
        def_file: METRICS,
        encode: FnRef { file: METRICS, name: "to_ckpt_json", owner: Some("RoundRecord") },
        decode: FnRef { file: METRICS, name: "from_ckpt_json", owner: Some("RoundRecord") },
    },
    ParityContract {
        type_name: "Strategy",
        kind: TargetKind::Enum,
        def_file: "rust/src/fl/strategy.rs",
        encode: FnRef {
            file: "rust/src/fl/strategy.rs",
            name: "checkpoint",
            owner: Some("Strategy"),
        },
        decode: FnRef {
            file: "rust/src/fl/strategy.rs",
            name: "restore",
            owner: Some("Strategy"),
        },
    },
    ParityContract {
        type_name: "ClusterSchedule",
        kind: TargetKind::Enum,
        def_file: "rust/src/fl/scheduler.rs",
        encode: FnRef {
            file: "rust/src/fl/scheduler.rs",
            name: "checkpoint",
            owner: Some("ClusterSchedule"),
        },
        decode: FnRef {
            file: "rust/src/fl/scheduler.rs",
            name: "restore",
            owner: Some("ClusterSchedule"),
        },
    },
];

/// The metrics CSV schema contract (header const vs row encoder).
pub const DEFAULT_CSV: [CsvContract; 1] = [CsvContract {
    record: "RoundRecord",
    file: METRICS,
    header_const: "METRICS_CSV_HEADER",
    row_fn: FnRef { file: METRICS, name: "csv_fields", owner: Some("RoundRecord") },
}];

/// The config surface contracts: every field of a declarative-surface
/// struct must appear in each of its parse/emit fns.  `ExperimentConfig`
/// additionally requires a CLI override arm; `CampaignSpec` (the
/// campaign file format) has no per-field CLI surface by design — only
/// its execution knobs are flag-overridable — so its contract covers
/// the JSON round-trip pair.
pub const DEFAULT_CONFIG: [ConfigContract; 2] = [
    ConfigContract {
        type_name: "ExperimentConfig",
        def_file: "rust/src/config/mod.rs",
        surfaces: &[
            (
                FnRef {
                    file: "rust/src/config/mod.rs",
                    name: "to_json",
                    owner: Some("ExperimentConfig"),
                },
                "JSON emit",
            ),
            (
                FnRef {
                    file: "rust/src/config/mod.rs",
                    name: "from_json",
                    owner: Some("ExperimentConfig"),
                },
                "JSON parse arm",
            ),
            (
                FnRef { file: "rust/src/cli/mod.rs", name: "apply_overrides", owner: None },
                "CLI override arm",
            ),
        ],
    },
    ConfigContract {
        type_name: "CampaignSpec",
        def_file: "rust/src/fl/campaign/spec.rs",
        surfaces: &[
            (
                FnRef {
                    file: "rust/src/fl/campaign/spec.rs",
                    name: "to_json",
                    owner: Some("CampaignSpec"),
                },
                "JSON emit",
            ),
            (
                FnRef {
                    file: "rust/src/fl/campaign/spec.rs",
                    name: "from_json",
                    owner: Some("CampaignSpec"),
                },
                "JSON parse arm",
            ),
        ],
    },
];

/// Run every default contract over the analyzed tree.
pub fn apply(analyses: &mut [FileAnalysis]) {
    apply_with(analyses, &DEFAULT_PARITY, &DEFAULT_CSV, &DEFAULT_CONFIG);
}

/// Run explicit contract tables (the fixture tests drive this with
/// synthetic tables; [`apply`] is the production entry point).
pub fn apply_with(
    analyses: &mut [FileAnalysis],
    parity: &[ParityContract],
    csv: &[CsvContract],
    config: &[ConfigContract],
) {
    let mut findings: Vec<(usize, usize, Rule, String)> = Vec::new();
    for c in parity {
        check_parity(analyses, c, &mut findings);
    }
    for c in csv {
        check_csv(analyses, c, &mut findings);
    }
    for c in config {
        check_config(analyses, c, &mut findings);
    }
    for (file_idx, line_idx, rule, message) in findings {
        analyses[file_idx].report(line_idx, rule, message);
    }
}

fn idx_of(analyses: &[FileAnalysis], rel: &str) -> Option<usize> {
    analyses.iter().position(|fa| fa.rel == rel)
}

/// Whether `word` appears (word-bounded) in the fn-body span of the
/// file — in the masked code view or the string-literal view.
fn span_contains(fa: &FileAnalysis, span: (usize, usize), word: &str) -> bool {
    let lo = span.0.saturating_sub(1);
    let hi = span.1.min(fa.code.len());
    for i in lo..hi {
        if count_word(&fa.code[i], word) > 0 || count_word(&fa.strings[i], word) > 0 {
            return true;
        }
    }
    false
}

/// Resolve a fn anchor to (analysis index, body span).  On failure,
/// push a violation at `anchor_line` of `anchor_idx` and return None.
fn resolve_fn(
    analyses: &[FileAnalysis],
    fr: &FnRef,
    rule: Rule,
    anchor_idx: usize,
    anchor_line: usize,
    findings: &mut Vec<(usize, usize, Rule, String)>,
) -> Option<(usize, (usize, usize))> {
    let i = match idx_of(analyses, fr.file) {
        Some(i) => i,
        None => return None, // anchor file outside the scanned set
    };
    match analyses[i].items.fn_named(fr.name, fr.owner) {
        Some(f) => match f.body {
            Some(span) => Some((i, span)),
            None => {
                findings.push((
                    anchor_idx,
                    anchor_line,
                    rule,
                    format!("contract fn {} has no body to check", fr.describe()),
                ));
                None
            }
        },
        None => {
            findings.push((
                anchor_idx,
                anchor_line,
                rule,
                format!(
                    "contract fn {} not found — if it moved or was renamed, \
                     update the contract table in lint/src/contracts.rs",
                    fr.describe()
                ),
            ));
            None
        }
    }
}

/// The fields a parity target contributes: a struct's named fields,
/// or every named field of every variant of an enum.
fn target_fields(
    fa: &FileAnalysis,
    type_name: &str,
    kind: TargetKind,
) -> Option<(usize, Vec<(String, usize)>)> {
    match kind {
        TargetKind::Struct => fa.items.struct_named(type_name).map(|s| {
            (
                s.line,
                s.fields.iter().map(|f| (f.name.clone(), f.line)).collect(),
            )
        }),
        TargetKind::Enum => fa.items.enum_named(type_name).map(|e| {
            (
                e.line,
                e.variants
                    .iter()
                    .flat_map(|v| v.fields.iter().map(|f| (f.name.clone(), f.line)))
                    .collect(),
            )
        }),
    }
}

fn check_parity(
    analyses: &[FileAnalysis],
    c: &ParityContract,
    findings: &mut Vec<(usize, usize, Rule, String)>,
) {
    let def_i = match idx_of(analyses, c.def_file) {
        Some(i) => i,
        None => return,
    };
    let (type_line, fields) =
        match target_fields(&analyses[def_i], c.type_name, c.kind) {
            Some(x) => x,
            None => {
                findings.push((
                    def_i,
                    0,
                    Rule::CheckpointParity,
                    format!(
                        "contract type `{}` not found in {} — if it moved or \
                         was renamed, update the contract table in \
                         lint/src/contracts.rs",
                        c.type_name, c.def_file
                    ),
                ));
                return;
            }
        };
    let anchor = type_line - 1;
    let enc = resolve_fn(
        analyses,
        &c.encode,
        Rule::CheckpointParity,
        def_i,
        anchor,
        findings,
    );
    let dec = resolve_fn(
        analyses,
        &c.decode,
        Rule::CheckpointParity,
        def_i,
        anchor,
        findings,
    );
    for (name, line) in &fields {
        for (side, resolved, fr) in
            [("encode", enc, &c.encode), ("decode", dec, &c.decode)]
        {
            let (fn_i, span) = match resolved {
                Some(x) => x,
                None => continue,
            };
            if !span_contains(&analyses[fn_i], span, name) {
                findings.push((
                    def_i,
                    line - 1,
                    Rule::CheckpointParity,
                    format!(
                        "field `{}` of {} never appears in its {} fn {} — a \
                         field that skips serialization breaks resume \
                         bit-identity (serialize it, or justify with \
                         lint:allow(checkpoint-parity))",
                        name,
                        c.type_name,
                        side,
                        fr.describe()
                    ),
                ));
            }
        }
    }
}

/// Ordered `self.<field>` references in a fn body (first occurrence
/// per field), from the masked code view.
fn self_field_refs(fa: &FileAnalysis, span: (usize, usize)) -> Vec<String> {
    let mut refs: Vec<String> = Vec::new();
    let lo = span.0.saturating_sub(1);
    let hi = span.1.min(fa.code.len());
    for line in &fa.code[lo..hi] {
        let bytes = line.as_bytes();
        let mut start = 0;
        while let Some(p) = line[start..].find("self.") {
            let p = start + p;
            let before_ok = p == 0
                || !(bytes[p - 1].is_ascii_alphanumeric() || bytes[p - 1] == b'_');
            let mut end = p + "self.".len();
            while end < bytes.len()
                && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_')
            {
                end += 1;
            }
            let name = &line[p + "self.".len()..end];
            if before_ok && !name.is_empty() && !refs.iter().any(|r| r == name) {
                refs.push(name.to_string());
            }
            start = p + "self.".len();
        }
    }
    refs
}

/// Header columns in declaration order: whitespace-separated tokens
/// of the string-literal view over the const's span.
fn header_columns(fa: &FileAnalysis, span: (usize, usize)) -> Vec<(String, usize)> {
    let mut cols = Vec::new();
    let lo = span.0.saturating_sub(1);
    let hi = span.1.min(fa.strings.len());
    for i in lo..hi {
        for tok in fa.strings[i].split_whitespace() {
            cols.push((tok.to_string(), i));
        }
    }
    cols
}

fn check_csv(
    analyses: &[FileAnalysis],
    c: &CsvContract,
    findings: &mut Vec<(usize, usize, Rule, String)>,
) {
    let i = match idx_of(analyses, c.file) {
        Some(i) => i,
        None => return,
    };
    let fa = &analyses[i];
    let rec = match fa.items.struct_named(c.record) {
        Some(r) => r,
        None => {
            findings.push((
                i,
                0,
                Rule::CsvSchemaParity,
                format!("contract type `{}` not found in {}", c.record, c.file),
            ));
            return;
        }
    };
    let hc = match fa.items.const_named(c.header_const) {
        Some(h) => h,
        None => {
            findings.push((
                i,
                rec.line - 1,
                Rule::CsvSchemaParity,
                format!("header const `{}` not found in {}", c.header_const, c.file),
            ));
            return;
        }
    };
    let row = match resolve_fn(analyses, &c.row_fn, Rule::CsvSchemaParity, i, rec.line - 1, findings)
    {
        Some((ri, span)) => {
            debug_assert_eq!(ri, i);
            Some(span)
        }
        None => None,
    };

    let cols = header_columns(fa, hc.span);
    let fields: Vec<(&str, usize)> = rec
        .fields
        .iter()
        .map(|f| (f.name.as_str(), f.line))
        .collect();

    if cols.len() != fields.len() {
        findings.push((
            i,
            hc.line - 1,
            Rule::CsvSchemaParity,
            format!(
                "{} has {} columns but {} has {} fields — header and record \
                 must stay in lockstep",
                c.header_const,
                cols.len(),
                c.record,
                fields.len()
            ),
        ));
    }
    for (name, line) in &fields {
        if !cols.iter().any(|(col, _)| col == name) {
            findings.push((
                i,
                line - 1,
                Rule::CsvSchemaParity,
                format!(
                    "field `{}` of {} has no {} column — exports would \
                     silently drop it",
                    name, c.record, c.header_const
                ),
            ));
        }
    }
    for (col, col_line) in &cols {
        if !fields.iter().any(|(name, _)| name == col) {
            findings.push((
                i,
                *col_line,
                Rule::CsvSchemaParity,
                format!(
                    "{} column \"{}\" matches no {} field",
                    c.header_const, col, c.record
                ),
            ));
        }
    }
    if let Some(span) = row {
        let refs = self_field_refs(fa, span);
        for (name, line) in &fields {
            if !refs.iter().any(|r| r == name) {
                findings.push((
                    i,
                    line - 1,
                    Rule::CsvSchemaParity,
                    format!(
                        "field `{}` of {} is never referenced by {} — the \
                         row encoder would emit a short or stale row",
                        name,
                        c.record,
                        c.row_fn.describe()
                    ),
                ));
            }
        }
        // Column order must match the encoder's reference order.
        for (k, (col, _)) in cols.iter().enumerate() {
            match refs.get(k) {
                Some(r) if r == col => {}
                Some(r) => {
                    findings.push((
                        i,
                        hc.line - 1,
                        Rule::CsvSchemaParity,
                        format!(
                            "column order diverges at position {k}: header \
                             says \"{col}\" but {} emits `self.{r}` there",
                            c.row_fn.describe()
                        ),
                    ));
                    break;
                }
                None => break, // count mismatch already reported
            }
        }
    }
}

fn check_config(
    analyses: &[FileAnalysis],
    c: &ConfigContract,
    findings: &mut Vec<(usize, usize, Rule, String)>,
) {
    let def_i = match idx_of(analyses, c.def_file) {
        Some(i) => i,
        None => return,
    };
    let (type_line, fields) =
        match target_fields(&analyses[def_i], c.type_name, TargetKind::Struct) {
            Some(x) => x,
            None => {
                findings.push((
                    def_i,
                    0,
                    Rule::ConfigSurfaceParity,
                    format!(
                        "contract type `{}` not found in {}",
                        c.type_name, c.def_file
                    ),
                ));
                return;
            }
        };
    for (fr, what) in c.surfaces {
        let (fn_i, span) = match resolve_fn(
            analyses,
            fr,
            Rule::ConfigSurfaceParity,
            def_i,
            type_line - 1,
            findings,
        ) {
            Some(x) => x,
            None => continue,
        };
        for (name, line) in &fields {
            if !span_contains(&analyses[fn_i], span, name) {
                findings.push((
                    def_i,
                    line - 1,
                    Rule::ConfigSurfaceParity,
                    format!(
                        "field `{}` of {} has no {} in {} — wire the field \
                         through, or justify the gap with \
                         lint:allow(config-surface-parity)",
                        name,
                        c.type_name,
                        what,
                        fr.describe()
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::analyze;

    const PARITY: [ParityContract; 1] = [ParityContract {
        type_name: "Snap",
        kind: TargetKind::Struct,
        def_file: "rust/src/fl/snap.rs",
        encode: FnRef { file: "rust/src/fl/snap.rs", name: "enc", owner: Some("Snap") },
        decode: FnRef { file: "rust/src/fl/snap.rs", name: "dec", owner: Some("Snap") },
    }];

    fn run_parity(src: &str) -> Vec<crate::Diagnostic> {
        let mut analyses = vec![analyze("rust/src/fl/snap.rs", src)];
        apply_with(&mut analyses, &PARITY, &[], &[]);
        let mut fa = analyses.pop().expect("one analysis");
        fa.finish();
        fa.diagnostics
    }

    #[test]
    fn parity_flags_field_missing_from_decode() {
        let src = "\
pub struct Snap {
    pub cursor: usize,
    pub clock: f64,
}
impl Snap {
    pub fn enc(&self) -> String {
        format_pair(self.cursor, self.clock)
    }
    pub fn dec(s: &str) -> Snap {
        Snap { cursor: parse(s), clock: 0.0 }
    }
}
";
        assert!(run_parity(src).is_empty());

        // Drop the decode-side mention of `clock` (clock_default does
        // not word-match the field name).
        let drifted = src.replace("clock: 0.0", "clock_default()");
        let diags = run_parity(&drifted);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, crate::Rule::CheckpointParity);
        assert_eq!(diags[0].line, 3); // the `clock` field line
        assert!(diags[0].message.contains("decode"));
    }

    #[test]
    fn parity_sees_string_keys() {
        // The field only appears as a serialized key "clock" in enc —
        // the string view must make that count.
        let src = "\
pub struct Snap {
    pub clock: f64,
}
impl Snap {
    pub fn enc(&self) -> String {
        emit(\"clock\", hex(self.clock_value()))
    }
    pub fn dec(s: &str) -> Snap {
        Snap { clock: parse(s) }
    }
}
";
        assert!(run_parity(src).is_empty());
    }

    #[test]
    fn parity_enum_checks_variant_fields() {
        let contracts = [ParityContract {
            type_name: "Cur",
            kind: TargetKind::Enum,
            def_file: "rust/src/fl/snap.rs",
            encode: FnRef { file: "rust/src/fl/snap.rs", name: "enc", owner: Some("Cur") },
            decode: FnRef { file: "rust/src/fl/snap.rs", name: "dec", owner: Some("Cur") },
        }];
        let src = "\
pub enum Cur {
    Seq { cursor: usize, skipped: usize },
    Plain,
}
impl Cur {
    pub fn enc(&self) -> String {
        emit(\"cursor\")
    }
    pub fn dec(s: &str) -> Cur {
        read(\"cursor\", s)
    }
}
";
        let mut analyses = vec![analyze("rust/src/fl/snap.rs", src)];
        apply_with(&mut analyses, &contracts, &[], &[]);
        let diags = &analyses[0].diagnostics;
        // `skipped` missing from both enc and dec.
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.line == 2));
    }

    #[test]
    fn parity_flags_renamed_anchor_fn() {
        let src = "\
pub struct Snap {
    pub cursor: usize,
}
impl Snap {
    pub fn encode_v2(&self) -> String {
        hex(self.cursor)
    }
    pub fn dec(s: &str) -> Snap {
        Snap { cursor: parse(s) }
    }
}
";
        let diags = run_parity(src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].message.contains("not found"));
    }

    const CSV: [CsvContract; 1] = [CsvContract {
        record: "Row",
        file: "rust/src/metrics/mod.rs",
        header_const: "HDR",
        row_fn: FnRef { file: "rust/src/metrics/mod.rs", name: "csv_fields", owner: Some("Row") },
    }];

    fn run_csv(src: &str) -> Vec<crate::Diagnostic> {
        let mut analyses = vec![analyze("rust/src/metrics/mod.rs", src)];
        apply_with(&mut analyses, &[], &CSV, &[]);
        let mut fa = analyses.pop().expect("one analysis");
        fa.finish();
        fa.diagnostics
    }

    #[test]
    fn csv_clean_when_header_matches() {
        let src = "\
pub struct Row {
    pub round: usize,
    pub loss: f64,
}
pub const HDR: [&str; 2] = [\"round\", \"loss\"];
impl Row {
    pub fn csv_fields(&self) -> Vec<String> {
        vec![self.round.to_string(), self.loss.to_string()]
    }
}
";
        assert!(run_csv(src).is_empty());
    }

    #[test]
    fn csv_flags_count_membership_and_order() {
        // Header misses `loss`, carries a phantom `lost`, and the
        // encoder emits loss where the header says lost.
        let src = "\
pub struct Row {
    pub round: usize,
    pub loss: f64,
}
pub const HDR: [&str; 2] = [\"round\", \"lost\"];
impl Row {
    pub fn csv_fields(&self) -> Vec<String> {
        vec![self.round.to_string(), self.loss.to_string()]
    }
}
";
        let diags = run_csv(src);
        let msgs: Vec<&str> = diags.iter().map(|d| d.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("no HDR column")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("matches no Row field")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("order diverges")), "{msgs:?}");
    }

    #[test]
    fn config_surface_checks_each_surface() {
        let config_src = "\
pub struct Cfg {
    pub rounds: usize,
    pub fresh: f64,
}
impl Cfg {
    pub fn to_json(&self) -> String {
        emit(\"rounds\", self.rounds, \"fresh\", self.fresh)
    }
    pub fn from_json(s: &str) -> Cfg {
        Cfg { rounds: get(s, \"rounds\"), fresh: get(s, \"fresh\") }
    }
}
";
        let cli_src = "\
pub fn apply_overrides(mut cfg: Cfg) -> Cfg {
    cfg.rounds = flag(\"rounds\");
    cfg
}
";
        const SURFACES: &[(FnRef, &'static str)] = &[
            (
                FnRef { file: "rust/src/config/mod.rs", name: "to_json", owner: Some("Cfg") },
                "JSON emit",
            ),
            (
                FnRef { file: "rust/src/config/mod.rs", name: "from_json", owner: Some("Cfg") },
                "JSON parse arm",
            ),
            (
                FnRef { file: "rust/src/cli/mod.rs", name: "apply_overrides", owner: None },
                "CLI override arm",
            ),
        ];
        let contracts = [ConfigContract {
            type_name: "Cfg",
            def_file: "rust/src/config/mod.rs",
            surfaces: SURFACES,
        }];
        let mut analyses = vec![
            analyze("rust/src/config/mod.rs", config_src),
            analyze("rust/src/cli/mod.rs", cli_src),
        ];
        apply_with(&mut analyses, &[], &[], &contracts);
        let diags = &analyses[0].diagnostics;
        // `fresh` has JSON emit + parse but no CLI arm.
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, crate::Rule::ConfigSurfaceParity);
        assert_eq!(diags[0].line, 3);
        assert!(diags[0].message.contains("CLI override arm"));
    }
}
