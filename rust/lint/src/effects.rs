//! Layer-3 interprocedural effect analysis: seed per-fn effect sets,
//! propagate them over the call graph to a fixpoint, and enforce the
//! three transitive determinism rules with witness call chains.
//!
//! Effects seeded per fn (non-test code only):
//!
//! * `wall-clock` — `Instant`/`SystemTime` anywhere except
//!   `rust/src/obs/wallclock.rs` (the one sanctioned wall-clock
//!   surface; the *local* rule's wider allowlist deliberately does
//!   not apply here — a `util/timer.rs` read is locally fine but
//!   still taints every caller on a determinism-critical surface).
//! * `unordered-iteration` — `HashMap`/`HashSet` construction.
//! * `rng-construction` — entropy-seeded RNG sources (`thread_rng`,
//!   `from_entropy`, `OsRng`, `RandomState`); the repo's own `Rng` is
//!   always explicitly seeded and does not taint.
//! * `panic` — `.unwrap()`/`.expect(`/`panic!` sites.
//! * `ambient-state` — `std::env` reads.
//! * `unsafe` — unsafe blocks/fns (audited locally by
//!   `unsafe-audit`; carried here for the effects artifact).
//!
//! A seed site suppressed by a justified local pragma
//! (`wall-clock-in-sim`, `unwrap-in-library`) or by the transitive
//! rule's own pragma does **not** taint: the pragma states the
//! invariant that makes the site safe, so propagating it anyway would
//! make every justification site poison its whole caller tree.
//!
//! The three rules, all reported at the *root* fn's signature line so
//! a `lint:allow` there can carry the justification:
//!
//! * `transitive-wall-clock` — fns on the runner/NetSim/report/
//!   serialization surfaces must not *reach* a wall-clock read
//!   (depth ≥ 1; direct reads are the local rule's job).
//! * `panic-reachability` — public `fl/`/`runtime/` API fns must not
//!   reach an unjustified panic site (depth ≥ 1).
//! * `pure-local-update` — `LocalUpdateHandle::run` impls must reach
//!   no wall-clock, RNG or ambient-state effect at any depth
//!   (including direct): a local update is a pure function of
//!   `(state, batch, lr)`.

use std::collections::{BTreeMap, VecDeque};

use crate::callgraph::{self, CallGraph};
use crate::report::esc;
use crate::rules::FileAnalysis;
use crate::{Rule, WitnessHop};

pub const WALL: u8 = 1;
pub const UNORDERED: u8 = 2;
pub const RNG: u8 = 4;
pub const PANIC: u8 = 8;
pub const AMBIENT: u8 = 16;
pub const UNSAFE: u8 = 32;

/// Stable kind names, in the order chains pick a kind to blame when a
/// target carries several banned effects.
pub const KINDS: [(u8, &str); 6] = [
    (WALL, "wall-clock"),
    (RNG, "rng-construction"),
    (AMBIENT, "ambient-state"),
    (PANIC, "panic"),
    (UNORDERED, "unordered-iteration"),
    (UNSAFE, "unsafe"),
];

/// The only file allowed to seed no wall-clock effect: the dual-clock
/// boundary of the obs layer.
const WALL_CLOCK_SANCTUARY: &str = "rust/src/obs/wallclock.rs";

/// Determinism-critical surfaces whose fns are `transitive-wall-clock`
/// roots: the runner/session/aggregation loop, the NetSim DES, and
/// every report/serialization path.  Mirrors `scope::UNORDERED_SCOPE`
/// minus `obs/` (whose wall-clock half is the sanctioned dual-clock
/// design).
const WALL_ROOT_SURFACES: [&str; 8] = [
    "rust/src/fl/runner.rs",
    "rust/src/fl/session.rs",
    "rust/src/fl/aggregate.rs",
    "rust/src/netsim/",
    "rust/src/metrics/",
    "rust/src/util/json.rs",
    "rust/src/util/csv.rs",
    "rust/src/runtime/params.rs",
];

/// Layers whose public fns are `panic-reachability` roots.
const PANIC_ROOT_SURFACES: [&str; 2] = ["rust/src/fl/", "rust/src/runtime/"];

/// Anchor trait of the `pure-local-update` contract, declared here so
/// a rename in `runtime/backend.rs` breaks the lint loudly instead of
/// silently guarding nothing.
const LOCAL_UPDATE_TRAIT: &str = "LocalUpdateHandle";
const LOCAL_UPDATE_METHOD: &str = "run";
const LOCAL_UPDATE_ANCHOR_FILE: &str = "rust/src/runtime/backend.rs";

const PURE_BANNED: u8 = WALL | RNG | AMBIENT;

/// One fn's effect sets in the machine-readable artifact.
#[derive(Clone, Debug)]
pub struct FnEffects {
    pub func: String,
    pub file: String,
    /// 1-based signature line.
    pub line: usize,
    pub direct: Vec<&'static str>,
    pub transitive: Vec<&'static str>,
}

/// One unresolved call in the artifact.
#[derive(Clone, Debug)]
pub struct UnresolvedSummary {
    pub func: String,
    pub file: String,
    /// The callee as written (`fs::read`, `.push`, `helper`).
    pub call: String,
    /// 1-based call-site line.
    pub line: usize,
}

/// The effects/witness artifact (`--effects-out`): every fn with a
/// non-empty effect set, plus every call the resolver could not map
/// to an in-tree fn (recorded, never silently dropped).
#[derive(Default)]
pub struct EffectsSummary {
    pub fns: Vec<FnEffects>,
    pub unresolved: Vec<UnresolvedSummary>,
}

/// Schema version of the effects artifact.
pub const EFFECTS_VERSION: u64 = 1;

impl EffectsSummary {
    /// Render the artifact as deterministic JSON.
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"version\": {EFFECTS_VERSION},\n"));
        out.push_str("  \"fns\": [");
        for (k, f) in self.fns.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{ \"fn\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"direct\": [{}], \"transitive\": [{}] }}",
                esc(&f.func),
                esc(&f.file),
                f.line,
                kind_list(&f.direct),
                kind_list(&f.transitive),
            ));
        }
        out.push_str(if self.fns.is_empty() { "],\n" } else { "\n  ],\n" });
        out.push_str("  \"unresolved\": [");
        for (k, u) in self.unresolved.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{ \"fn\": \"{}\", \"file\": \"{}\", \"call\": \"{}\", \
                 \"line\": {} }}",
                esc(&u.func),
                esc(&u.file),
                esc(&u.call),
                u.line,
            ));
        }
        out.push_str(if self.unresolved.is_empty() {
            "]\n"
        } else {
            "\n  ]\n"
        });
        out.push_str("}\n");
        out
    }
}

fn kind_list(kinds: &[&'static str]) -> String {
    kinds
        .iter()
        .map(|k| format!("\"{k}\""))
        .collect::<Vec<_>>()
        .join(", ")
}

fn kind_names(mask: u8) -> Vec<&'static str> {
    KINDS
        .iter()
        .filter(|(bit, _)| mask & bit != 0)
        .map(|&(_, name)| name)
        .collect()
}

/// Run the whole interprocedural pass over the analyzed tree: build
/// the call graph, seed and propagate effects, enforce the three
/// transitive rules, and return the artifact summary.
pub fn apply(analyses: &mut [FileAnalysis]) -> EffectsSummary {
    let g = callgraph::build(analyses);
    let (direct, sites) = seed(&g, analyses);
    let transitive = propagate(&g, &direct);

    enforce_wall_clock(&g, analyses, &direct, &transitive, &sites);
    enforce_panic_reachability(&g, analyses, &direct, &transitive, &sites);
    enforce_pure_local_update(&g, analyses, &direct, &transitive, &sites);

    summarize(&g, &direct, &transitive)
}

/// First seed site per (node, effect bit), for witness terminals.
type Sites = BTreeMap<(usize, u8), usize>;

/// Scan every graph file line by line, honoring pragmas and test
/// regions, and attribute each seed to the innermost enclosing fn.
fn seed(g: &CallGraph, analyses: &mut [FileAnalysis]) -> (Vec<u8>, Sites) {
    let mut direct = vec![0u8; g.nodes.len()];
    let mut sites: Sites = BTreeMap::new();

    // Per analysis file: the graph nodes with bodies in it.
    let mut file_nodes: BTreeMap<usize, Vec<(usize, usize, usize)>> = BTreeMap::new();
    for (ni, n) in g.nodes.iter().enumerate() {
        if let Some((s, e)) = n.body {
            file_nodes.entry(n.file).or_default().push((s, e, ni));
        }
    }

    for (&fi, spans) in &file_nodes {
        let fa = &mut analyses[fi];
        let in_sanctuary = fa.rel == WALL_CLOCK_SANCTUARY;
        for i in 0..fa.code.len() {
            if fa.line_is_test(i) {
                continue;
            }
            let line = std::mem::take(&mut fa.code[i]);
            let mut mask = 0u8;
            if !in_sanctuary
                && crate::rules::count_word(&line, "Instant")
                    + crate::rules::count_word(&line, "SystemTime")
                    > 0
            {
                // Either pragma justifies the read; consume both so a
                // doubled grant cannot go stale.
                let local = fa.consume_allow(i, Rule::WallClockInSim.id());
                let transitive = fa.consume_allow(i, Rule::TransitiveWallClock.id());
                if !(local || transitive) {
                    mask |= WALL;
                }
            }
            if crate::rules::count_word(&line, ".unwrap()")
                + crate::rules::count_word(&line, ".expect(")
                + crate::rules::count_word(&line, "panic!")
                > 0
            {
                let local = fa.consume_allow(i, Rule::UnwrapInLibrary.id());
                let transitive = fa.consume_allow(i, Rule::PanicReachability.id());
                if !(local || transitive) {
                    mask |= PANIC;
                }
            }
            if crate::rules::count_word(&line, "HashMap")
                + crate::rules::count_word(&line, "HashSet")
                > 0
            {
                mask |= UNORDERED;
            }
            if crate::rules::count_word(&line, "thread_rng")
                + crate::rules::count_word(&line, "from_entropy")
                + crate::rules::count_word(&line, "OsRng")
                + crate::rules::count_word(&line, "RandomState")
                > 0
            {
                mask |= RNG;
            }
            if crate::rules::count_word(&line, "env::var")
                + crate::rules::count_word(&line, "env::vars")
                + crate::rules::count_word(&line, "env::var_os")
                + crate::rules::count_word(&line, "env::args")
                + crate::rules::count_word(&line, "env::args_os")
                > 0
            {
                mask |= AMBIENT;
            }
            if crate::rules::count_word(&line, "unsafe") > 0 {
                mask |= UNSAFE;
            }
            fa.code[i] = line;
            if mask == 0 {
                continue;
            }
            let src_line = i + 1;
            let node = spans
                .iter()
                .filter(|&&(s, e, _)| s <= src_line && src_line <= e)
                .max_by_key(|&&(s, _, _)| s)
                .map(|&(_, _, ni)| ni);
            let ni = match node {
                Some(ni) => ni,
                // Seed outside any fn body (const initializer): no
                // caller can reach it through the graph.
                None => continue,
            };
            direct[ni] |= mask;
            for (bit, _) in KINDS {
                if mask & bit != 0 {
                    sites.entry((ni, bit)).or_insert(src_line);
                }
            }
        }
    }
    (direct, sites)
}

/// Propagate effect sets along call edges to a fixpoint.
fn propagate(g: &CallGraph, direct: &[u8]) -> Vec<u8> {
    let mut trans = direct.to_vec();
    loop {
        let mut changed = false;
        for ni in 0..g.nodes.len() {
            let mut m = trans[ni];
            for &(callee, _) in &g.edges[ni] {
                m |= trans[callee];
            }
            if m != trans[ni] {
                trans[ni] = m;
                changed = true;
            }
        }
        if !changed {
            return trans;
        }
    }
}

/// BFS a shortest witness chain from `root` to any fn whose *direct*
/// effects intersect `mask`.  With `include_root`, a direct effect on
/// the root itself is a one-hop chain; otherwise the search starts at
/// the root's callees (direct effects are the local rules' job).
/// Deterministic: edges are sorted and BFS order is fixed.
fn find_chain(
    g: &CallGraph,
    root: usize,
    mask: u8,
    include_root: bool,
    direct: &[u8],
    sites: &Sites,
) -> Option<Vec<WitnessHop>> {
    let hit = |ni: usize| direct[ni] & mask != 0;
    let terminal = |ni: usize| -> WitnessHop {
        let bit = KINDS
            .iter()
            .map(|&(b, _)| b)
            .find(|b| direct[ni] & b & mask != 0)
            .unwrap_or(0);
        WitnessHop {
            func: g.nodes[ni].display(),
            file: g.nodes[ni].rel.clone(),
            line: sites
                .get(&(ni, bit))
                .copied()
                .unwrap_or(g.nodes[ni].line),
        }
    };
    if include_root && hit(root) {
        return Some(vec![terminal(root)]);
    }
    let mut parent: Vec<Option<(usize, usize)>> = vec![None; g.nodes.len()];
    let mut visited = vec![false; g.nodes.len()];
    visited[root] = true;
    let mut queue = VecDeque::from([root]);
    while let Some(u) = queue.pop_front() {
        for &(v, line) in &g.edges[u] {
            if visited[v] {
                continue;
            }
            visited[v] = true;
            parent[v] = Some((u, line));
            if hit(v) {
                // Reconstruct root → … → v.
                let mut rev: Vec<(usize, usize)> = Vec::new();
                let mut cur = v;
                while let Some((p, l)) = parent[cur] {
                    rev.push((cur, l));
                    cur = p;
                }
                let mut hops: Vec<WitnessHop> = Vec::new();
                let mut at = root;
                for &(next, call_line) in rev.iter().rev() {
                    hops.push(WitnessHop {
                        func: g.nodes[at].display(),
                        file: g.nodes[at].rel.clone(),
                        line: call_line,
                    });
                    at = next;
                }
                hops.push(terminal(v));
                return Some(hops);
            }
            queue.push_back(v);
        }
    }
    None
}

/// `A -> B -> C` chain text plus the effect site, for the message.
fn chain_text(hops: &[WitnessHop], verb: &str) -> String {
    let funcs: Vec<&str> = hops.iter().map(|h| h.func.as_str()).collect();
    let last = hops.last().expect("chains have at least one hop");
    format!(
        "{} {} at {}:{}",
        funcs.join(" -> "),
        verb,
        last.file,
        last.line
    )
}

/// Whether a node is eligible as a rule root: has a body and is not
/// test code.
fn is_root_candidate(
    g: &CallGraph,
    analyses: &[FileAnalysis],
    ni: usize,
) -> bool {
    let n = &g.nodes[ni];
    n.body.is_some() && !analyses[n.file].line_is_test(n.line.saturating_sub(1))
}

fn enforce_wall_clock(
    g: &CallGraph,
    analyses: &mut [FileAnalysis],
    direct: &[u8],
    transitive: &[u8],
    sites: &Sites,
) {
    for ni in 0..g.nodes.len() {
        let rel = g.nodes[ni].rel.clone();
        if !WALL_ROOT_SURFACES.iter().any(|p| rel.starts_with(p))
            || !is_root_candidate(g, analyses, ni)
        {
            continue;
        }
        // Depth ≥ 1 only: does any callee transitively reach a seed?
        let reaches = g.edges[ni]
            .iter()
            .any(|&(c, _)| transitive[c] & WALL != 0);
        if !reaches {
            continue;
        }
        let hops = match find_chain(g, ni, WALL, false, direct, sites) {
            Some(h) => h,
            None => continue,
        };
        let msg = format!(
            "wall-clock read reachable from determinism-critical fn \
             `{}`: {}; route timing through obs::wallclock, or justify \
             the seed site or this fn with lint:allow(transitive-wall-clock)",
            g.nodes[ni].display(),
            chain_text(&hops, "reads the wall clock"),
        );
        let line_idx = g.nodes[ni].line - 1;
        let file = g.nodes[ni].file;
        analyses[file].report_witnessed(line_idx, Rule::TransitiveWallClock, msg, hops);
    }
}

fn enforce_panic_reachability(
    g: &CallGraph,
    analyses: &mut [FileAnalysis],
    direct: &[u8],
    transitive: &[u8],
    sites: &Sites,
) {
    for ni in 0..g.nodes.len() {
        let n = &g.nodes[ni];
        if !n.is_pub
            || !PANIC_ROOT_SURFACES.iter().any(|p| n.rel.starts_with(p))
            || !is_root_candidate(g, analyses, ni)
        {
            continue;
        }
        let reaches = g.edges[ni]
            .iter()
            .any(|&(c, _)| transitive[c] & PANIC != 0);
        if !reaches {
            continue;
        }
        let hops = match find_chain(g, ni, PANIC, false, direct, sites) {
            Some(h) => h,
            None => continue,
        };
        let msg = format!(
            "unjustified panic site reachable from public API fn `{}`: \
             {}; return a typed util::error Result along the chain, \
             justify the panic site, or justify this fn with \
             lint:allow(panic-reachability)",
            g.nodes[ni].display(),
            chain_text(&hops, "can panic"),
        );
        let line_idx = g.nodes[ni].line - 1;
        let file = g.nodes[ni].file;
        analyses[file].report_witnessed(line_idx, Rule::PanicReachability, msg, hops);
    }
}

fn enforce_pure_local_update(
    g: &CallGraph,
    analyses: &mut [FileAnalysis],
    direct: &[u8],
    transitive: &[u8],
    sites: &Sites,
) {
    let mut found_impl = false;
    for ni in 0..g.nodes.len() {
        let n = &g.nodes[ni];
        if n.trait_of.as_deref() != Some(LOCAL_UPDATE_TRAIT)
            || n.name != LOCAL_UPDATE_METHOD
            || n.body.is_none()
        {
            continue;
        }
        found_impl = true;
        if transitive[ni] & PURE_BANNED == 0 {
            continue;
        }
        let hops = match find_chain(g, ni, PURE_BANNED, true, direct, sites) {
            Some(h) => h,
            None => continue,
        };
        let kinds = kind_names(transitive[ni] & PURE_BANNED).join(", ");
        let msg = format!(
            "{}::{} impl `{}` reaches a non-pure effect ({}): {}; a \
             local update must be a pure function of (state, batch, \
             lr) — hoist the effect into backend setup or justify \
             with lint:allow(pure-local-update)",
            LOCAL_UPDATE_TRAIT,
            LOCAL_UPDATE_METHOD,
            g.nodes[ni].display(),
            kinds,
            chain_text(&hops, "performs the effect"),
        );
        let line_idx = g.nodes[ni].line - 1;
        let file = g.nodes[ni].file;
        analyses[file].report_witnessed(line_idx, Rule::PureLocalUpdate, msg, hops);
    }
    // Anchor guard: if the trait's home file is in the scanned tree
    // but no impl parses anywhere, the contract guards nothing.
    if !found_impl {
        if let Some(fi) = analyses
            .iter()
            .position(|fa| fa.rel == LOCAL_UPDATE_ANCHOR_FILE)
        {
            analyses[fi].report(
                0,
                Rule::PureLocalUpdate,
                format!(
                    "trait `{LOCAL_UPDATE_TRAIT}` has no impls anywhere in \
                     the scanned tree — the pure-local-update contract \
                     guards nothing; update the anchor in \
                     lint/src/effects.rs if the trait was renamed or moved"
                ),
            );
        }
    }
}

fn summarize(g: &CallGraph, direct: &[u8], transitive: &[u8]) -> EffectsSummary {
    let mut fns: Vec<FnEffects> = (0..g.nodes.len())
        .filter(|&ni| direct[ni] | transitive[ni] != 0)
        .map(|ni| FnEffects {
            func: g.nodes[ni].display(),
            file: g.nodes[ni].rel.clone(),
            line: g.nodes[ni].line,
            direct: kind_names(direct[ni]),
            transitive: kind_names(transitive[ni]),
        })
        .collect();
    fns.sort_by(|a, b| {
        (&a.file, a.line, &a.func).cmp(&(&b.file, b.line, &b.func))
    });
    let mut unresolved: Vec<UnresolvedSummary> = g
        .unresolved
        .iter()
        .map(|u| UnresolvedSummary {
            func: g.nodes[u.from].display(),
            file: g.nodes[u.from].rel.clone(),
            call: u.name.clone(),
            line: u.line,
        })
        .collect();
    unresolved.sort_by(|a, b| {
        (&a.file, a.line, &a.call).cmp(&(&b.file, b.line, &b.call))
    });
    EffectsSummary { fns, unresolved }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::analyze;

    fn run(files: &[(&str, &str)]) -> (Vec<FileAnalysis>, EffectsSummary) {
        let mut analyses: Vec<FileAnalysis> =
            files.iter().map(|(rel, src)| analyze(rel, src)).collect();
        let summary = apply(&mut analyses);
        for fa in &mut analyses {
            fa.finish();
        }
        (analyses, summary)
    }

    fn all_diags(analyses: &[FileAnalysis]) -> Vec<&crate::Diagnostic> {
        analyses.iter().flat_map(|fa| fa.diagnostics.iter()).collect()
    }

    #[test]
    fn two_hop_wall_clock_chain_is_found() {
        let runner = "\
pub fn drive() {
    middle();
}
";
        let util = "\
pub fn middle() {
    leaf();
}
pub fn leaf() {
    let _t = std::time::Instant::now();
}
";
        let (analyses, _s) = run(&[
            ("rust/src/fl/runner.rs", runner),
            ("rust/src/fl/support.rs", util),
        ]);
        let diags = all_diags(&analyses);
        let wall: Vec<_> = diags
            .iter()
            .filter(|d| d.rule == Rule::TransitiveWallClock)
            .collect();
        assert_eq!(wall.len(), 1, "{diags:?}");
        assert_eq!(wall[0].file, "rust/src/fl/runner.rs");
        assert_eq!(wall[0].line, 1);
        let funcs: Vec<&str> =
            wall[0].witness.iter().map(|h| h.func.as_str()).collect();
        assert_eq!(funcs, ["drive", "middle", "leaf"]);
        // Terminal hop points at the effect site, not the fn line.
        assert_eq!(wall[0].witness[2].line, 5);
    }

    #[test]
    fn direct_wall_clock_is_left_to_the_local_rule() {
        let runner = "\
pub fn drive() {
    let _t = std::time::Instant::now();
}
";
        let (analyses, _s) = run(&[("rust/src/fl/runner.rs", runner)]);
        let diags = all_diags(&analyses);
        assert!(
            diags.iter().any(|d| d.rule == Rule::WallClockInSim),
            "{diags:?}"
        );
        assert!(
            !diags.iter().any(|d| d.rule == Rule::TransitiveWallClock),
            "{diags:?}"
        );
    }

    #[test]
    fn pragma_at_seed_site_stops_the_taint() {
        let runner = "\
pub fn drive() {
    middle();
}
";
        let util = "\
pub fn middle() {
    // lint:allow(transitive-wall-clock): log-only timing, never
    // enters any report or simulated-time decision.
    let _t = std::time::Instant::now();
}
";
        let (analyses, _s) = run(&[
            ("rust/src/fl/runner.rs", runner),
            ("rust/src/fl/support.rs", util),
        ]);
        let diags = all_diags(&analyses);
        assert!(
            !diags.iter().any(|d| d.rule == Rule::TransitiveWallClock),
            "{diags:?}"
        );
    }

    #[test]
    fn panic_reachability_spares_private_and_test_fns() {
        let src = "\
pub fn api() {
    helper();
}
fn helper() {
    inner_panics();
}
fn inner_panics() {
    panic!(\"boom\");
}
#[cfg(test)]
mod tests {
    pub fn test_only() {
        super::inner_panics();
    }
}
";
        let (analyses, _s) = run(&[("rust/src/runtime/pool.rs", src)]);
        let diags = all_diags(&analyses);
        let hits: Vec<_> = diags
            .iter()
            .filter(|d| d.rule == Rule::PanicReachability)
            .collect();
        // Only the public root fires; private helpers and the test fn
        // do not (the panic! itself also trips the local rule).
        assert_eq!(hits.len(), 1, "{diags:?}");
        assert_eq!(hits[0].line, 1);
        let funcs: Vec<&str> =
            hits[0].witness.iter().map(|h| h.func.as_str()).collect();
        assert_eq!(funcs, ["api", "helper", "inner_panics"]);
    }

    #[test]
    fn pure_local_update_catches_direct_and_transitive_effects() {
        let src = "\
pub trait LocalUpdateHandle {
    fn run(&self) -> usize;
}
pub struct B;
impl LocalUpdateHandle for B {
    fn run(&self) -> usize {
        seeded();
        0
    }
}
fn seeded() {
    let _ = thread_rng();
}
";
        let (analyses, _s) = run(&[("rust/src/runtime/backend.rs", src)]);
        let diags = all_diags(&analyses);
        let hits: Vec<_> = diags
            .iter()
            .filter(|d| d.rule == Rule::PureLocalUpdate)
            .collect();
        assert_eq!(hits.len(), 1, "{diags:?}");
        assert_eq!(hits[0].line, 6);
        assert!(hits[0].message.contains("rng-construction"));
    }

    #[test]
    fn missing_local_update_anchor_is_loud() {
        let src = "\
pub trait RenamedHandle {
    fn run(&self) -> usize;
}
";
        let (analyses, _s) = run(&[("rust/src/runtime/backend.rs", src)]);
        let diags = all_diags(&analyses);
        assert!(
            diags.iter().any(|d| d.rule == Rule::PureLocalUpdate
                && d.message.contains("has no impls")),
            "{diags:?}"
        );
    }

    #[test]
    fn summary_records_effects_and_unresolved_calls() {
        let src = "\
pub fn a() {
    b();
}
fn b() {
    let _t = std::time::Instant::now();
    mystery();
}
";
        let (_analyses, s) = run(&[("rust/src/topology/graph.rs", src)]);
        let a = s.fns.iter().find(|f| f.func == "a").expect("a");
        assert!(a.direct.is_empty());
        assert_eq!(a.transitive, ["wall-clock"]);
        let b = s.fns.iter().find(|f| f.func == "b").expect("b");
        assert_eq!(b.direct, ["wall-clock"]);
        // Both calls the resolver cannot see through are recorded:
        // `Instant::now` (std) and the undefined `mystery`.
        let calls: Vec<&str> =
            s.unresolved.iter().map(|u| u.call.as_str()).collect();
        assert_eq!(calls, ["Instant::now", "mystery"]);
        // The artifact renders and stays deterministic.
        let json = s.render_json();
        assert!(json.contains("\"wall-clock\""));
        assert!(json.contains("\"mystery\""));
    }
}
