//! Comment/string-stripping tokenizer.
//!
//! Rules must never fire on the *text* of a string literal or a
//! comment, so the engine scans a masked view of each source file:
//! every character that belongs to a string/char literal or a comment
//! is replaced by a space in the `code` view (line and column layout
//! is preserved, so token adjacency still works), while comment text
//! is routed to the parallel `comment` view where the pragma and
//! `SAFETY:` scanners look for it.
//!
//! The tokenizer understands: `//`-style line comments (incl. `///`
//! and `//!` doc comments), nested `/* */` block comments, plain and
//! byte string literals with `\"`/`\\` escapes, raw (byte) strings
//! `r"…"` / `r#"…"#` / `br"…"`, char and byte-char literals, and
//! tells lifetimes (`'a`) apart from char literals (`'a'`).

/// A source file split into a per-line masked code view, a per-line
/// comment-text view, and a per-line string-literal view.  All three
/// vectors have one entry per source line.
pub struct Masked {
    /// Source lines with strings, char literals and comments blanked.
    pub code: Vec<String>,
    /// Comment text per line (`//` bodies and `/* */` interiors).
    pub comment: Vec<String>,
    /// String-literal contents per line, at their source columns, with
    /// everything else blanked.  The delimiting quotes themselves are
    /// blanked too, so adjacent literals never fuse into one token.
    /// Contract rules search this view for serialized key names
    /// (`"deadline_s"`, CSV column headers) that the code view hides.
    pub strings: Vec<String>,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    /// Nesting depth of `/* */` comments (Rust block comments nest).
    BlockComment(usize),
    Str,
    /// Raw string terminated by `"` followed by this many `#`s.
    RawStr(usize),
    /// Inside an escape-form char literal (`'\…'`).
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Mask `source` into parallel code/comment/string line views.
pub fn mask(source: &str) -> Masked {
    let chars: Vec<char> = source.chars().collect();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut strings = Vec::new();
    let mut code_line = String::new();
    let mut comment_line = String::new();
    let mut string_line = String::new();
    let mut st = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, State::LineComment) {
                st = State::Code;
            }
            code.push(std::mem::take(&mut code_line));
            comment.push(std::mem::take(&mut comment_line));
            strings.push(std::mem::take(&mut string_line));
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = State::LineComment;
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = State::BlockComment(1);
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    i += 2;
                } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(chars[i - 1])) {
                    // Possible raw/byte string opener: r" r#" br" b"
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let has_r = c == 'r' || j > i + 1;
                    let mut hashes = 0;
                    while has_r && chars.get(j + hashes) == Some(&'#') {
                        hashes += 1;
                    }
                    if has_r && chars.get(j + hashes) == Some(&'"') {
                        for _ in i..=(j + hashes) {
                            code_line.push(' ');
                            string_line.push(' ');
                        }
                        st = State::RawStr(hashes);
                        i = j + hashes + 1;
                    } else if c == 'b' && next == Some('"') {
                        code_line.push_str("  ");
                        string_line.push_str("  ");
                        st = State::Str;
                        i += 2;
                    } else {
                        code_line.push(c);
                        string_line.push(' ');
                        i += 1;
                    }
                } else if c == '"' {
                    st = State::Str;
                    code_line.push(' ');
                    string_line.push(' ');
                    i += 1;
                } else if c == '\'' {
                    let n1 = chars.get(i + 1).copied();
                    if n1 == Some('\\') {
                        // Escape-form char literal: '\n' '\'' '\u{..}'
                        st = State::CharLit;
                        code_line.push(' ');
                        string_line.push(' ');
                        i += 1;
                    } else if n1.is_some()
                        && n1 != Some('\'')
                        && chars.get(i + 2) == Some(&'\'')
                    {
                        // Simple one-char literal like 'a' or '"'.
                        code_line.push_str("   ");
                        string_line.push_str("   ");
                        i += 3;
                    } else {
                        // A lifetime ('a, 'static): plain code.
                        code_line.push(c);
                        string_line.push(' ');
                        i += 1;
                    }
                } else {
                    code_line.push(c);
                    string_line.push(' ');
                    i += 1;
                }
            }
            State::LineComment => {
                code_line.push(' ');
                string_line.push(' ');
                comment_line.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    st = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    st = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    code_line.push(' ');
                    string_line.push(' ');
                    comment_line.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && chars.get(i + 1).is_some() && chars[i + 1] != '\n' {
                    // Escape sequences are blanked in the string view:
                    // serialized key names never contain escapes, and a
                    // bare escaped char could fuse with neighbours into
                    // a phantom token.
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code_line.push(' ');
                    string_line.push(' ');
                    st = State::Code;
                    i += 1;
                } else {
                    code_line.push(' ');
                    string_line.push(c);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut k = 0;
                    while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                        k += 1;
                    }
                    if k == hashes {
                        for _ in 0..=hashes {
                            code_line.push(' ');
                            string_line.push(' ');
                        }
                        st = State::Code;
                        i += hashes + 1;
                    } else {
                        code_line.push(' ');
                        string_line.push(c);
                        i += 1;
                    }
                } else {
                    code_line.push(' ');
                    string_line.push(c);
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' && chars.get(i + 1).is_some() && chars[i + 1] != '\n' {
                    code_line.push_str("  ");
                    string_line.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    code_line.push(' ');
                    string_line.push(' ');
                    st = State::Code;
                    i += 1;
                } else {
                    code_line.push(' ');
                    string_line.push(' ');
                    i += 1;
                }
            }
        }
    }
    code.push(code_line);
    comment.push(comment_line);
    strings.push(string_line);
    Masked {
        code,
        comment,
        strings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_blanked() {
        let m = mask("let s = \"a.partial_cmp(&b).unwrap()\";");
        assert!(!m.code[0].contains("partial_cmp"), "{:?}", m.code[0]);
        assert!(m.code[0].contains("let s ="));
    }

    #[test]
    fn line_comments_go_to_comment_view() {
        let m = mask("let x = 1; // Instant::now() here is prose\n");
        assert!(!m.code[0].contains("Instant"));
        assert!(m.comment[0].contains("Instant::now()"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner unsafe */ still SystemTime */ b";
        let m = mask(src);
        assert!(!m.code[0].contains("unsafe"));
        assert!(!m.code[0].contains("SystemTime"));
        assert!(m.code[0].contains('a') && m.code[0].contains('b'));
        assert!(m.comment[0].contains("SystemTime"));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let m = mask("let r = r#\"HashMap \"quoted\" panic!\"#; let y = 2;");
        assert!(!m.code[0].contains("HashMap"));
        assert!(!m.code[0].contains("panic"));
        assert!(m.code[0].contains("let y = 2;"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // A '"' char literal must not open a string.
        let m = mask("let q = '\"'; let z = 3; // tail");
        assert!(m.code[0].contains("let z = 3;"));
        // Lifetimes survive as code.
        let m = mask("fn f<'a>(x: &'a f64) -> &'a f64 { x }");
        assert!(m.code[0].contains("fn f<'a>"));
    }

    #[test]
    fn escaped_quotes_stay_inside_strings() {
        let m = mask(r#"let s = "esc \" unsafe { } \\"; let k = 4;"#);
        assert!(!m.code[0].contains("unsafe"));
        assert!(m.code[0].contains("let k = 4;"));
    }

    #[test]
    fn multiline_strings_stay_masked() {
        let m = mask("let s = \"line one\n  partial_cmp line two\";\nlet t = 5;");
        assert!(!m.code[1].contains("partial_cmp"));
        assert!(m.code[2].contains("let t = 5;"));
    }

    #[test]
    fn byte_strings_are_blanked() {
        let m = mask("let b = b\"unsafe bytes\"; let w = 6;");
        assert!(!m.code[0].contains("unsafe"));
        assert!(m.code[0].contains("let w = 6;"));
    }

    #[test]
    fn division_is_not_a_comment() {
        let m = mask("let x = a / b / c;");
        assert_eq!(m.code[0], "let x = a / b / c;");
    }

    #[test]
    fn string_view_preserves_literal_text() {
        let m = mask("let k = json_get(\"deadline_s\"); let x = 1;");
        assert!(m.strings[0].contains("deadline_s"), "{:?}", m.strings[0]);
        assert!(!m.strings[0].contains("json_get"));
        assert!(!m.strings[0].contains("let x"));
    }

    #[test]
    fn string_view_keeps_adjacent_literals_apart() {
        // The blanked quotes must separate back-to-back literals.
        let m = mask("[\"round\",\"cluster\"]");
        let toks: Vec<&str> = m.strings[0].split_whitespace().collect();
        assert_eq!(toks, ["round", "cluster"]);
    }

    #[test]
    fn string_view_blanks_comments_and_chars() {
        let m = mask("let c = 'x'; // \"not a literal\"");
        assert!(m.strings[0].trim().is_empty(), "{:?}", m.strings[0]);
    }

    #[test]
    fn string_view_covers_raw_strings() {
        let m = mask("let r = r#\"raw_key\"#;");
        assert!(m.strings[0].contains("raw_key"));
    }

    #[test]
    fn string_view_blanks_escapes() {
        let m = mask(r#"let s = "a\nb";"#);
        let toks: Vec<&str> = m.strings[0].split_whitespace().collect();
        assert_eq!(toks, ["a", "b"]);
    }

    #[test]
    fn views_stay_column_aligned() {
        let src = "let s = \"key\"; foo(s); // note\nbar();";
        let m = mask(src);
        for (line, src_line) in src.lines().enumerate() {
            let n = src_line.chars().count();
            assert_eq!(m.code[line].chars().count(), n);
            assert_eq!(m.strings[line].chars().count(), n);
        }
    }
}
