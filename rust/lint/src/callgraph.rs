//! Layer-3 call-site resolution: walk the masked token stream of
//! every `rust/src/` file and resolve calls against the item table
//! ([`crate::items`] fn signatures and body spans).
//!
//! Resolution is best-effort and *over-approximating* — a call that
//! could target several in-tree fns produces an edge to each.  The
//! policy, per call shape:
//!
//! * `self.name(…)` — fns named `name` owned by the enclosing impl's
//!   type (dyn/trait dispatch keeps the over-approximation sound).
//! * `Type::name(…)` — fns named `name` owned by `Type`.  A
//!   capitalized name with no match is treated as a tuple-struct or
//!   enum-variant constructor (`Error::Artifact(…)`), not a call.
//! * `module::name(…)` (lowercase qualifier) — free fns named `name`
//!   in files whose stem is `module` (`timer::start` → a fn in
//!   `util/timer.rs`); `self::`/`super::`/`crate::` qualifiers are
//!   stripped and resolve like bare calls.
//! * `recv.name(…)` — every method named `name` anywhere in the
//!   graph; narrowed to same-file candidates when any exist.
//! * `name(…)` — free fns named `name`, same-file first.  A
//!   capitalized bare name with no match is a constructor, not a call.
//!
//! Macro invocations (`name!(…)`) and `fn` definitions are skipped.
//! Every *other* unresolved call — typically std/core methods the
//! tree does not define — is recorded in [`CallGraph::unresolved`],
//! never silently dropped: the effects artifact surfaces them so a
//! reviewer can audit what the analysis could not see through.
//!
//! Only files under `rust/src/` participate: roots never live in
//! tests/benches, and indexing test helpers would let a test-only fn
//! capture call edges by name collision.

use std::collections::BTreeMap;

use crate::items::{lex, Tok, Token};
use crate::rules::FileAnalysis;

/// One fn in the graph, denormalized from its [`crate::items::FnItem`].
pub struct FnNode {
    /// Index into the analysis slice the graph was built from.
    pub file: usize,
    /// Repo-relative path (copied for display convenience).
    pub rel: String,
    pub name: String,
    pub owner: Option<String>,
    pub trait_of: Option<String>,
    pub is_pub: bool,
    /// 1-based signature line.
    pub line: usize,
    /// Inclusive 1-based body span; `None` for trait signatures.
    pub body: Option<(usize, usize)>,
}

impl FnNode {
    /// Display name: `Owner::name` for methods, bare `name` otherwise.
    pub fn display(&self) -> String {
        match &self.owner {
            Some(o) => format!("{}::{}", o, self.name),
            None => self.name.clone(),
        }
    }
}

/// A call the resolver could not map to any in-tree fn.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct UnresolvedCall {
    /// Caller node index.
    pub from: usize,
    /// The callee as written (`fs::read`, `.push`, `helper`).
    pub name: String,
    /// 1-based call-site line.
    pub line: usize,
}

/// The whole-tree call graph over `rust/src/` fns.
pub struct CallGraph {
    pub nodes: Vec<FnNode>,
    /// Per-node outgoing edges as `(callee node, 1-based call line)`,
    /// sorted by callee with the first call site kept.
    pub edges: Vec<Vec<(usize, usize)>>,
    /// Unresolved calls, sorted and deduplicated.
    pub unresolved: Vec<UnresolvedCall>,
}

/// Keywords and call-position constructs that are never call targets.
const NON_CALL_IDENTS: [&str; 18] = [
    "if", "else", "while", "for", "in", "match", "loop", "return", "move",
    "let", "as", "ref", "mut", "break", "continue", "where", "await", "fn",
];

fn is_capitalized(name: &str) -> bool {
    name.chars().next().is_some_and(|c| c.is_ascii_uppercase())
}

/// Build the call graph for every `rust/src/` file in `analyses`.
pub fn build(analyses: &[FileAnalysis]) -> CallGraph {
    let mut nodes = Vec::new();
    // (file idx in `analyses`) -> (node range start).
    let mut file_of_graph: Vec<usize> = Vec::new();
    for (fi, fa) in analyses.iter().enumerate() {
        if !fa.rel.starts_with("rust/src/") {
            continue;
        }
        file_of_graph.push(fi);
        for f in &fa.items.fns {
            nodes.push(FnNode {
                file: fi,
                rel: fa.rel.clone(),
                name: f.name.clone(),
                owner: f.owner.clone(),
                trait_of: f.trait_of.clone(),
                is_pub: f.is_pub,
                line: f.line,
                body: f.body,
            });
        }
    }

    // Name → node indices, and file stem → node indices.
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut by_stem: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (ni, n) in nodes.iter().enumerate() {
        by_name.entry(n.name.as_str()).or_default().push(ni);
        by_stem.entry(file_stem(&n.rel)).or_default().push(ni);
    }

    let mut edges: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nodes.len()];
    let mut unresolved: Vec<UnresolvedCall> = Vec::new();

    for &fi in &file_of_graph {
        let fa = &analyses[fi];
        // Innermost-fn lookup for call-site attribution: nested fns
        // have narrower spans than the fn that encloses them.
        let mut spans: Vec<(usize, usize, usize)> = nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.file == fi)
            .filter_map(|(ni, n)| n.body.map(|(s, e)| (s, e, ni)))
            .collect();
        spans.sort();
        let enclosing = |line: usize| -> Option<usize> {
            spans
                .iter()
                .filter(|&&(s, e, _)| s <= line && line <= e)
                .max_by_key(|&&(s, _, _)| s)
                .map(|&(_, _, ni)| ni)
        };

        let toks = lex(&fa.code);
        for j in 0..toks.len() {
            let name = match &toks[j].tok {
                Tok::Ident(s) => s.as_str(),
                Tok::Punct(_) => continue,
            };
            if NON_CALL_IDENTS.contains(&name) || !args_follow(&toks, j) {
                continue;
            }
            // `fn name(` is a definition, not a call.
            if j > 0 && toks[j - 1].tok == Tok::Ident("fn".into()) {
                continue;
            }
            let line = toks[j].line;
            let caller = match enclosing(line) {
                Some(c) => c,
                // Call in const/static initializer position: no
                // enclosing fn to attribute it to.
                None => continue,
            };
            let shape = classify(&toks, j);
            let targets = resolve(&shape, name, fi, &nodes, &by_name, &by_stem);
            match targets {
                Resolution::Edges(ts) => {
                    for t in ts {
                        edges[caller].push((t, line));
                    }
                }
                Resolution::Constructor => {}
                Resolution::Unresolved(written) => unresolved.push(UnresolvedCall {
                    from: caller,
                    name: written,
                    line,
                }),
            }
        }
    }

    for list in &mut edges {
        list.sort();
        list.dedup_by_key(|e| e.0);
    }
    unresolved.sort();
    unresolved.dedup_by(|a, b| a.from == b.from && a.name == b.name);

    CallGraph {
        nodes,
        edges,
        unresolved,
    }
}

/// The file stem module calls resolve against: the file name without
/// `.rs`, or the parent directory for `mod.rs`.
fn file_stem(rel: &str) -> String {
    let parts: Vec<&str> = rel.split('/').collect();
    let last = parts.last().copied().unwrap_or("");
    let stem = last.strip_suffix(".rs").unwrap_or(last);
    if stem == "mod" {
        parts
            .get(parts.len().saturating_sub(2))
            .copied()
            .unwrap_or("")
            .to_string()
    } else {
        stem.to_string()
    }
}

/// Whether an argument list follows the identifier at `j`, skipping a
/// turbofish (`collect::<Vec<_>>(…)`).  A `name!(…)` macro is not a
/// call.
fn args_follow(toks: &[Token], j: usize) -> bool {
    let mut k = j + 1;
    if matches!(toks.get(k), Some(t) if t.tok == Tok::Punct('!')) {
        return false;
    }
    if matches!(toks.get(k), Some(t) if t.tok == Tok::Punct(':'))
        && matches!(toks.get(k + 1), Some(t) if t.tok == Tok::Punct(':'))
        && matches!(toks.get(k + 2), Some(t) if t.tok == Tok::Punct('<'))
    {
        // Skip the turbofish generics with the same `->`-aware
        // counting the item parser uses.
        let mut depth = 0i64;
        let mut prev_minus = false;
        k += 2;
        loop {
            let t = match toks.get(k) {
                Some(t) => t,
                None => return false,
            };
            k += 1;
            match t.tok {
                Tok::Punct('<') => {
                    depth += 1;
                    prev_minus = false;
                }
                Tok::Punct('>') => {
                    if prev_minus {
                        prev_minus = false;
                        continue;
                    }
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                Tok::Punct('-') => prev_minus = true,
                _ => prev_minus = false,
            }
        }
    }
    matches!(toks.get(k), Some(t) if t.tok == Tok::Punct('('))
}

enum Shape {
    /// `self.name(…)` or `Self::name(…)`: owner comes from the
    /// enclosing fn's impl block.
    SelfMethod,
    /// `Qual::name(…)` with a capitalized qualifier.
    TypeQualified(String),
    /// `qual::name(…)` with a lowercase qualifier (module path).
    ModuleQualified(String),
    /// `recv.name(…)`.
    Method,
    /// `name(…)`.
    Bare,
}

fn classify(toks: &[Token], j: usize) -> Shape {
    if j >= 1 {
        if let Tok::Punct('.') = toks[j - 1].tok {
            if j >= 2 && toks[j - 2].tok == Tok::Ident("self".into()) {
                // `x.self` cannot occur; `self.name(` is a self call.
                return Shape::SelfMethod;
            }
            return Shape::Method;
        }
    }
    if j >= 2
        && matches!(toks[j - 1].tok, Tok::Punct(':'))
        && matches!(toks[j - 2].tok, Tok::Punct(':'))
    {
        if j >= 3 {
            if let Tok::Ident(q) = &toks[j - 3].tok {
                return match q.as_str() {
                    "self" | "super" | "crate" => Shape::Bare,
                    "Self" => Shape::SelfMethod,
                    _ if is_capitalized(q) => Shape::TypeQualified(q.clone()),
                    _ => Shape::ModuleQualified(q.clone()),
                };
            }
        }
        // `<T as Trait>::name(` and friends: fall back to by-name
        // method resolution.
        return Shape::Method;
    }
    Shape::Bare
}

enum Resolution {
    Edges(Vec<usize>),
    /// Capitalized non-fn in call position: a constructor, by policy.
    Constructor,
    Unresolved(String),
}

fn resolve(
    shape: &Shape,
    name: &str,
    file: usize,
    nodes: &[FnNode],
    by_name: &BTreeMap<&str, Vec<usize>>,
    by_stem: &BTreeMap<String, Vec<usize>>,
) -> Resolution {
    let named: &[usize] = by_name.get(name).map(|v| v.as_slice()).unwrap_or(&[]);
    let prefer_same_file = |cands: Vec<usize>| -> Vec<usize> {
        let local: Vec<usize> =
            cands.iter().copied().filter(|&ni| nodes[ni].file == file).collect();
        if local.is_empty() {
            cands
        } else {
            local
        }
    };
    match shape {
        Shape::SelfMethod => {
            // Owner of the *caller's* impl block is not threaded here;
            // `self.name(` narrowed by owner presence is enough: a
            // receiver call can only land on a method.
            let cands: Vec<usize> = named
                .iter()
                .copied()
                .filter(|&ni| nodes[ni].owner.is_some())
                .collect();
            if cands.is_empty() {
                Resolution::Unresolved(format!("self.{name}"))
            } else {
                Resolution::Edges(prefer_same_file(cands))
            }
        }
        Shape::TypeQualified(q) => {
            let cands: Vec<usize> = named
                .iter()
                .copied()
                .filter(|&ni| nodes[ni].owner.as_deref() == Some(q.as_str()))
                .collect();
            if !cands.is_empty() {
                Resolution::Edges(cands)
            } else if is_capitalized(name) {
                // `Error::Artifact(…)`: an enum-variant constructor.
                Resolution::Constructor
            } else {
                Resolution::Unresolved(format!("{q}::{name}"))
            }
        }
        Shape::ModuleQualified(q) => {
            let in_stem: Vec<usize> = by_stem
                .get(q.as_str())
                .map(|v| {
                    v.iter()
                        .copied()
                        .filter(|&ni| nodes[ni].name == name)
                        .collect()
                })
                .unwrap_or_default();
            if in_stem.is_empty() {
                Resolution::Unresolved(format!("{q}::{name}"))
            } else {
                Resolution::Edges(in_stem)
            }
        }
        Shape::Method => {
            let cands: Vec<usize> = named
                .iter()
                .copied()
                .filter(|&ni| nodes[ni].owner.is_some())
                .collect();
            if cands.is_empty() {
                Resolution::Unresolved(format!(".{name}"))
            } else {
                Resolution::Edges(prefer_same_file(cands))
            }
        }
        Shape::Bare => {
            let cands: Vec<usize> = named
                .iter()
                .copied()
                .filter(|&ni| nodes[ni].owner.is_none())
                .collect();
            if !cands.is_empty() {
                Resolution::Edges(prefer_same_file(cands))
            } else if is_capitalized(name) {
                // `Some(…)`, `Ok(…)`, `Wrapper(…)`: constructors.
                Resolution::Constructor
            } else {
                Resolution::Unresolved(name.to_string())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::analyze;

    fn graph(files: &[(&str, &str)]) -> (Vec<FileAnalysis>, CallGraph) {
        let analyses: Vec<FileAnalysis> =
            files.iter().map(|(rel, src)| analyze(rel, src)).collect();
        let g = build(&analyses);
        (analyses, g)
    }

    fn node(g: &CallGraph, disp: &str) -> usize {
        g.nodes
            .iter()
            .position(|n| n.display() == disp)
            .unwrap_or_else(|| panic!("no node {disp}"))
    }

    fn calls(g: &CallGraph, from: &str, to: &str) -> bool {
        let f = node(g, from);
        let t = node(g, to);
        g.edges[f].iter().any(|&(c, _)| c == t)
    }

    #[test]
    fn bare_and_module_qualified_calls_resolve() {
        let (_a, g) = graph(&[
            (
                "rust/src/fl/a.rs",
                "pub fn entry() {\n    helper();\n    timer::start();\n}\nfn helper() {}\n",
            ),
            ("rust/src/util/timer.rs", "pub fn start() {}\n"),
        ]);
        assert!(calls(&g, "entry", "helper"));
        assert!(calls(&g, "entry", "start"));
    }

    #[test]
    fn type_qualified_and_method_calls_resolve() {
        let src_a = "\
pub struct W;
impl W {
    pub fn go(&self) {
        self.step();
        Other::make();
    }
    fn step(&self) {}
}
";
        let src_b = "\
pub struct Other;
impl Other {
    pub fn make() {}
    pub fn touch(&self) {}
}
pub fn drive(o: &Other) {
    o.touch();
}
";
        let (_a, g) =
            graph(&[("rust/src/fl/a.rs", src_a), ("rust/src/fl/b.rs", src_b)]);
        assert!(calls(&g, "W::go", "W::step"));
        assert!(calls(&g, "W::go", "Other::make"));
        assert!(calls(&g, "drive", "Other::touch"));
    }

    #[test]
    fn constructors_and_macros_are_not_calls() {
        let src = "\
pub enum E { V(usize) }
pub struct Wrap(usize);
pub fn f() -> Wrap {
    let _ = E::V(1);
    let _ = Some(2);
    println!(\"x\");
    Wrap(3)
}
";
        let (_a, g) = graph(&[("rust/src/fl/a.rs", src)]);
        let f = node(&g, "f");
        assert!(g.edges[f].is_empty());
        assert!(g.unresolved.is_empty(), "{:?}", g.unresolved);
    }

    #[test]
    fn unresolved_calls_are_recorded() {
        let src = "\
pub fn f(v: &mut Vec<usize>) {
    v.push(1);
    mystery();
    fs::read(\"x\");
}
";
        let (_a, g) = graph(&[("rust/src/fl/a.rs", src)]);
        let names: Vec<&str> =
            g.unresolved.iter().map(|u| u.name.as_str()).collect();
        assert_eq!(names, [".push", "fs::read", "mystery"]);
    }

    #[test]
    fn turbofish_is_a_call_shape() {
        let src = "\
pub fn f() {
    helper::<usize>();
}
pub fn helper<T>() {}
";
        let (_a, g) = graph(&[("rust/src/fl/a.rs", src)]);
        assert!(calls(&g, "f", "helper"));
    }

    #[test]
    fn nested_fn_calls_attribute_to_the_inner_fn() {
        let src = "\
pub fn outer() {
    fn inner() {
        leaf();
    }
    inner();
}
fn leaf() {}
";
        let (_a, g) = graph(&[("rust/src/fl/a.rs", src)]);
        assert!(calls(&g, "inner", "leaf"));
        assert!(calls(&g, "outer", "inner"));
        // The call inside `inner` belongs to `inner`, not `outer`.
        assert!(!calls(&g, "outer", "leaf"));
    }

    #[test]
    fn non_src_files_stay_out_of_the_graph() {
        let (_a, g) = graph(&[
            ("rust/src/fl/a.rs", "pub fn f() { helper(); }\n"),
            ("rust/tests/t.rs", "pub fn helper() {}\n"),
        ]);
        assert!(g.nodes.iter().all(|n| n.rel.starts_with("rust/src/")));
        assert_eq!(g.unresolved.len(), 1);
        assert_eq!(g.unresolved[0].name, "helper");
    }
}
