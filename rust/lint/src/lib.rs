//! `edgeflow-lint`: std-only static analysis that enforces EdgeFLow's
//! determinism & robustness contracts.
//!
//! The repo's headline guarantee — bit-identical reports at any worker
//! count, bit-identical checkpoint/resume — is a *social* contract
//! unless something machine-checks it.  This crate is that check.  It
//! scans `rust/src`, `rust/tests`, `rust/benches`, `examples` and its
//! own sources with a comment/string-stripping tokenizer
//! ([`tokenize`]), applies a per-module scope table ([`scope`]), and
//! enforces two tiers of rules.
//!
//! Local (single-file, [`rules`]):
//!
//! | rule | guards |
//! |------|--------|
//! | `float-ordering`      | NaN-sound orderings (PR 1 bit-identity) |
//! | `wall-clock-in-sim`   | the simulated clock (PR 2 NetSim DES)   |
//! | `unordered-iteration` | stable reduce/serialize order (PR 1/3)  |
//! | `unwrap-in-library`   | the typed-error surface (PR 3/4)        |
//! | `unsafe-audit`        | future SIMD/intrinsics kernels          |
//!
//! Cross-file (whole-tree only, [`items`] + [`contracts`]):
//!
//! | rule | guards |
//! |------|--------|
//! | `checkpoint-parity`     | every checkpointed field round-trips  |
//! | `csv-schema-parity`     | CSV header ↔ `RoundRecord` lockstep   |
//! | `config-surface-parity` | config JSON/CLI surface completeness  |
//! | `stale-pragma`          | `lint:allow` grants that died of churn|
//!
//! Interprocedural (whole-tree only, [`callgraph`] + [`effects`]):
//! call sites are resolved against the item table, per-fn effect sets
//! are seeded and propagated to a fixpoint, and violations carry a
//! *witness call chain* from the root fn to the effect site:
//!
//! | rule | guards |
//! |------|--------|
//! | `transitive-wall-clock` | no wall-clock read reachable from the  |
//! |                         | runner/NetSim/report surfaces          |
//! | `panic-reachability`    | no unjustified panic reachable from a  |
//! |                         | public `fl/`/`runtime/` API fn         |
//! | `pure-local-update`     | `LocalUpdateHandle::run` stays a pure  |
//! |                         | function (PR 4 contract)               |
//!
//! Diagnostics print as `file:line:rule: message`; `--format json`
//! emits the stable machine-readable schema ([`report`]), and
//! `--baseline` diffs against a previous JSON report so migrations
//! fail only on *new* findings.  The binary exits 0 when clean, 1 on
//! violations, 2 on usage or I/O errors.
//!
//! Deliberately dependency-free: the build image is offline and a
//! lint gate must never be the thing that breaks the build.

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

pub mod callgraph;
pub mod contracts;
pub mod effects;
pub mod items;
pub mod report;
pub mod rules;
pub mod scope;
pub mod tokenize;

pub use rules::{lint_source, LintOutcome};

/// The rule set.  `Pragma` is a meta-rule: it fires on malformed
/// `lint:allow` pragmas and cannot itself be allowed away.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    FloatOrdering,
    WallClockInSim,
    UnorderedIteration,
    UnwrapInLibrary,
    UnsafeAudit,
    CheckpointParity,
    CsvSchemaParity,
    ConfigSurfaceParity,
    TransitiveWallClock,
    PanicReachability,
    PureLocalUpdate,
    StalePragma,
    Pragma,
}

impl Rule {
    /// The rules a `lint:allow` pragma may name.
    pub const ENFORCED: [Rule; 12] = [
        Rule::FloatOrdering,
        Rule::WallClockInSim,
        Rule::UnorderedIteration,
        Rule::UnwrapInLibrary,
        Rule::UnsafeAudit,
        Rule::CheckpointParity,
        Rule::CsvSchemaParity,
        Rule::ConfigSurfaceParity,
        Rule::TransitiveWallClock,
        Rule::PanicReachability,
        Rule::PureLocalUpdate,
        Rule::StalePragma,
    ];

    /// Stable diagnostic / pragma identifier.
    pub fn id(self) -> &'static str {
        match self {
            Rule::FloatOrdering => "float-ordering",
            Rule::WallClockInSim => "wall-clock-in-sim",
            Rule::UnorderedIteration => "unordered-iteration",
            Rule::UnwrapInLibrary => "unwrap-in-library",
            Rule::UnsafeAudit => "unsafe-audit",
            Rule::CheckpointParity => "checkpoint-parity",
            Rule::CsvSchemaParity => "csv-schema-parity",
            Rule::ConfigSurfaceParity => "config-surface-parity",
            Rule::TransitiveWallClock => "transitive-wall-clock",
            Rule::PanicReachability => "panic-reachability",
            Rule::PureLocalUpdate => "pure-local-update",
            Rule::StalePragma => "stale-pragma",
            Rule::Pragma => "pragma",
        }
    }

    /// Resolve a pragma rule name.  Only the enforced rules resolve —
    /// `pragma` itself is not allowable.
    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ENFORCED.into_iter().find(|r| r.id() == id)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One hop of a witness call chain.  For intermediate hops `line` is
/// the call site inside `func` that reaches the next hop; for the
/// terminal hop it is the effect site itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WitnessHop {
    /// Display name: `Owner::name` for methods, `name` for free fns.
    pub func: String,
    pub file: String,
    /// 1-based source line (call site, or effect site on the last hop).
    pub line: usize,
}

/// One violation, formatted as `file:line:rule: message`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    /// The trimmed raw source line the finding points at (baseline
    /// diffing keys on it, so findings survive pure line shifts).
    pub snippet: String,
    /// Witness call chain from the root fn to the effect site; empty
    /// for every rule outside the interprocedural layer.
    pub witness: Vec<WitnessHop>,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message
        )
    }
}

/// Aggregate result of linting a set of files.
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    /// Violations silenced by a justified `lint:allow` pragma (kept
    /// whole so the JSON report can show them with `pragma:allowed`).
    pub suppressed: Vec<Diagnostic>,
    pub files_scanned: usize,
    /// Per-fn effect sets and unresolved calls from the
    /// interprocedural pass; empty for local-only scans
    /// ([`lint_paths`]).
    pub effects: effects::EffectsSummary,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Repo-relative directories the `--check` sweep covers.  The lint
/// lints itself; fixture directories are skipped by [`collect_rs`].
pub const SCAN_ROOTS: [&str; 5] = [
    "rust/src",
    "rust/tests",
    "rust/benches",
    "examples",
    "rust/lint/src",
];

/// Lint the whole tree under `repo_root` ([`SCAN_ROOTS`]): local
/// rules, cross-file contracts, and the stale-pragma pass.
pub fn lint_tree(repo_root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    for root in SCAN_ROOTS {
        let dir = repo_root.join(root);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for file in &files {
        let rel = file
            .strip_prefix(repo_root)
            .unwrap_or(file.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((rel, std::fs::read_to_string(file)?));
    }
    let pairs: Vec<(&str, &str)> = sources
        .iter()
        .map(|(r, s)| (r.as_str(), s.as_str()))
        .collect();
    Ok(lint_sources(&pairs))
}

/// Lint a set of in-memory `(rel_path, source)` files with the full
/// pipeline — local rules, default contract tables, interprocedural
/// effects, stale-pragma.  [`lint_tree`] is this over the real tree;
/// the fixture tests drive it with synthetic files under the contract
/// anchor paths.
pub fn lint_sources(files: &[(&str, &str)]) -> Report {
    let mut analyses = analyze_all(files);
    contracts::apply(&mut analyses);
    let summary = effects::apply(&mut analyses);
    let mut diagnostics = Vec::new();
    let mut suppressed = Vec::new();
    for fa in &mut analyses {
        rules::stale_pragma_pass(fa);
        diagnostics.append(&mut fa.diagnostics);
        suppressed.append(&mut fa.suppressed);
    }
    Report {
        diagnostics,
        suppressed,
        files_scanned: files.len(),
        effects: summary,
    }
}

/// How many worker threads the per-file analysis uses: the
/// `EDGEFLOW_LINT_THREADS` override, else available parallelism, else
/// 1.  The file analysis is pure and results are stitched back in
/// input order, so the thread count never changes the report.
fn lint_threads() -> usize {
    if let Ok(v) = std::env::var("EDGEFLOW_LINT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run [`rules::analyze`] over every file, fanned out across std
/// scoped threads in contiguous chunks.  Each chunk's analyses come
/// back in chunk order and chunks are concatenated in order, so the
/// output is byte-for-byte identical to a sequential map regardless
/// of thread count (pinned by a test in `tests/engine.rs`).
fn analyze_all(files: &[(&str, &str)]) -> Vec<rules::FileAnalysis> {
    let threads = lint_threads().min(files.len().max(1));
    if threads <= 1 {
        return files
            .iter()
            .map(|(rel, source)| rules::analyze(rel, source))
            .collect();
    }
    let chunk = files.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = files
            .chunks(chunk)
            .map(|part| {
                s.spawn(move || {
                    part.iter()
                        .map(|(rel, source)| rules::analyze(rel, source))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(files.len());
        for h in handles {
            match h.join() {
                Ok(mut part) => out.append(&mut part),
                // A worker panic is an engine bug; re-raise it rather
                // than returning a silently truncated report.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out
    })
}

/// Lint explicit files or directories (still rooted at `repo_root`
/// for scope-table purposes).  Local rules only: contract and
/// stale-pragma verdicts are meaningless on a partial tree.
pub fn lint_paths(repo_root: &Path, paths: &[PathBuf]) -> io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        if p.is_dir() {
            collect_rs(p, &mut files)?;
        } else {
            files.push(p.clone());
        }
    }
    files.sort();
    let mut diagnostics = Vec::new();
    let mut suppressed = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(repo_root)
            .unwrap_or(file.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        let source = std::fs::read_to_string(file)?;
        let mut fa = rules::analyze(&rel, &source);
        fa.finish();
        diagnostics.append(&mut fa.diagnostics);
        suppressed.append(&mut fa.suppressed);
    }
    Ok(Report {
        diagnostics,
        suppressed,
        files_scanned: files.len(),
        effects: effects::EffectsSummary::default(),
    })
}

/// Recursively collect `.rs` files in sorted order (so diagnostics
/// are stable run-to-run).  `fixtures` directories hold deliberately
/// violating lint-test inputs and are never part of the tree sweep;
/// `target` is build output.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<std::fs::DirEntry> =
        std::fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let file_type = entry.file_type()?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if file_type.is_dir() {
            if name == "fixtures" || name == "target" {
                continue;
            }
            collect_rs(&entry.path(), out)?;
        } else if name.ends_with(".rs") {
            out.push(entry.path());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_round_trip() {
        for rule in Rule::ENFORCED {
            assert_eq!(Rule::from_id(rule.id()), Some(rule));
        }
        assert_eq!(Rule::from_id("pragma"), None);
        assert_eq!(Rule::from_id("no-such-rule"), None);
    }

    #[test]
    fn diagnostic_format() {
        let d = Diagnostic {
            file: "rust/src/fl/compress.rs".into(),
            line: 165,
            rule: Rule::FloatOrdering,
            message: "msg".into(),
            snippet: "let x = a.partial_cmp(&b);".into(),
            witness: Vec::new(),
        };
        assert_eq!(
            d.to_string(),
            "rust/src/fl/compress.rs:165:float-ordering: msg"
        );
    }

    #[test]
    fn lint_sources_runs_the_full_pipeline() {
        // A dead pragma in a file with no other findings: only the
        // full pipeline (stale-pragma pass) can see it.
        let src = "\
// lint:allow(unwrap-in-library): guarded an unwrap that is gone
pub fn f() -> usize {
    2
}
";
        let report = lint_sources(&[("rust/src/fl/x.rs", src)]);
        assert_eq!(report.files_scanned, 1);
        assert_eq!(report.diagnostics.len(), 1, "{:?}", report.diagnostics);
        assert_eq!(report.diagnostics[0].rule, Rule::StalePragma);
    }
}
