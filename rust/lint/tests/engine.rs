//! End-to-end tests for the rule engine over the fixture corpus.
//!
//! Fixtures live in `tests/fixtures/` (never compiled, never swept by
//! the tree gate) and are linted under *synthetic* repo-relative
//! paths so each test exercises the scope table on purpose.

use edgeflow_lint::report::{new_findings, parse_baseline, render_json, suppressed_by_rule};
use edgeflow_lint::{lint_source, lint_sources, Rule};

fn rules_of(rel: &str, src: &str) -> Vec<Rule> {
    lint_source(rel, src).diagnostics.iter().map(|d| d.rule).collect()
}

#[test]
fn float_ordering_fires_on_partial_cmp_and_float_eq() {
    let src = include_str!("fixtures/float_ordering_fire.rs");
    // data/ is outside the unwrap scope, so only float-ordering fires.
    let out = lint_source("rust/src/data/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 2, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::FloatOrdering));
    let lines: Vec<usize> = out.diagnostics.iter().map(|d| d.line).collect();
    assert_eq!(lines, vec![5, 9]);
    assert!(out
        .diagnostics
        .iter()
        .any(|d| d.to_string().starts_with("rust/src/data/fixture.rs:5:float-ordering:")));
}

#[test]
fn float_ordering_clean_on_total_cmp_and_test_oracles() {
    let src = include_str!("fixtures/float_ordering_clean.rs");
    let out = lint_source("rust/src/data/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    // The same float == in a non-test position would fire: strip the
    // cfg(test) attribute and the oracle is no longer exempt.
    let stripped = src.replace("#[cfg(test)]", "");
    let out = lint_source("rust/src/data/fixture.rs", &stripped);
    assert!(!out.diagnostics.is_empty());
}

#[test]
fn wall_clock_fires_in_sim_modules_only() {
    let src = include_str!("fixtures/wall_clock_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    // Two tokens per line on the use, the signature and the body.
    assert_eq!(out.diagnostics.len(), 6, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::WallClockInSim));

    // Scope table: allowlisted modules stay silent on identical code.
    for quiet in [
        "rust/src/bench/fixture.rs",
        "rust/src/util/timer.rs",
        "rust/src/runtime/executor.rs",
        "rust/benches/bench_parallel.rs",
    ] {
        let out = lint_source(quiet, src);
        assert!(out.diagnostics.is_empty(), "{quiet}: {:#?}", out.diagnostics);
    }
}

#[test]
fn obs_wall_clock_fixture_triple() {
    // Fire: the obs core must not read the clock directly...
    let fire = include_str!("fixtures/obs_wall_clock_fire.rs");
    let out = lint_source("rust/src/obs/mod.rs", fire);
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::WallClockInSim));
    // ...while the wall-clock half of the dual-clock span is
    // allowlisted for identical code.
    let out = lint_source("rust/src/obs/wallclock.rs", fire);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);

    // Clean: opaque marks need no clock and no pragma.
    let clean = include_str!("fixtures/obs_wall_clock_clean.rs");
    let out = lint_sources(&[("rust/src/obs/mod.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    // Pragma: a justified grant suppresses and is not stale under the
    // whole-set pipeline.
    let pragma = include_str!("fixtures/obs_wall_clock_pragma.rs");
    let out = lint_sources(&[("rust/src/obs/mod.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
    assert_eq!(out.suppressed[0].rule, Rule::WallClockInSim);
}

#[test]
fn unordered_fires_in_determinism_critical_modules_only() {
    let fire = include_str!("fixtures/unordered_fire.rs");
    let out = lint_source("rust/src/fl/aggregate.rs", fire);
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out
        .diagnostics
        .iter()
        .all(|d| d.rule == Rule::UnorderedIteration));
    // Outside the scoped modules the same code is fine.
    assert!(rules_of("rust/src/topology/graph.rs", fire).is_empty());

    let clean = include_str!("fixtures/unordered_clean.rs");
    assert!(rules_of("rust/src/fl/aggregate.rs", clean).is_empty());
}

#[test]
fn unwrap_fires_in_library_code_not_tests() {
    let src = include_str!("fixtures/unwrap_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out
        .diagnostics
        .iter()
        .all(|d| d.rule == Rule::UnwrapInLibrary));
    // Whole-file test trees are exempt.
    assert!(rules_of("rust/tests/integration.rs", src).is_empty());
    // Outside fl/ and runtime/ the rule does not apply.
    assert!(rules_of("rust/src/cli/mod.rs", src).is_empty());
}

#[test]
fn justified_pragma_suppresses_and_counts() {
    let src = include_str!("fixtures/unwrap_pragma.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed, 1);
}

#[test]
fn pragma_without_reason_is_rejected_and_does_not_suppress() {
    let src = include_str!("fixtures/unwrap_pragma_bad.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    let rules = rules_of("rust/src/fl/fixture.rs", src);
    assert_eq!(rules, vec![Rule::Pragma, Rule::UnwrapInLibrary]);
    assert_eq!(out.suppressed, 0);
}

#[test]
fn pragma_attachment_breaks_at_blank_lines() {
    let src = "\
pub fn f(v: &[f32]) -> f32 {\n\
    // lint:allow(unwrap-in-library): blank line below detaches this.\n\
\n\
    *v.first().unwrap()\n\
}\n";
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::UnwrapInLibrary);
    assert_eq!(out.suppressed, 0);
}

#[test]
fn pragma_with_unknown_rule_is_flagged() {
    let src = "// lint:allow(no-such-rule): reasons\npub fn f() {}\n";
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 1);
    assert_eq!(out.diagnostics[0].rule, Rule::Pragma);
    assert!(out.diagnostics[0].message.contains("no-such-rule"));
}

#[test]
fn unsafe_requires_safety_comment() {
    let fire = include_str!("fixtures/unsafe_fire.rs");
    let out = lint_source("rust/src/data/fixture.rs", fire);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::UnsafeAudit);

    let ok = include_str!("fixtures/unsafe_safety_ok.rs");
    let out = lint_source("rust/src/data/fixture.rs", ok);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
}

#[test]
fn tokenizer_tricky_file_is_silent() {
    let src = include_str!("fixtures/tokenizer_tricky.rs");
    // Lint under the most aggressive scope combination: fl/ paths get
    // float-ordering, wall-clock, unwrap and unsafe all enabled.
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    let out = lint_source("rust/src/fl/aggregate.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
}

// ------------------------------------------------------- contract rules
//
// The cross-file rules only run in the whole-set pipeline, so these
// triples drive `lint_sources` with fixtures under the *real* anchor
// paths (absent anchor files skip a contract, which is why e.g. the
// metrics fixture also carries the checkpoint round-trip fns).

#[test]
fn checkpoint_parity_fixture_triple() {
    let fire = include_str!("fixtures/ckpt_parity_fire.rs");
    let out = lint_sources(&[("rust/src/rng/mod.rs", fire)]);
    // `stream` is missing from the encode AND the decode side.
    assert_eq!(out.diagnostics.len(), 2, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::CheckpointParity));
    assert!(out.diagnostics.iter().all(|d| d.message.contains("`stream`")));

    let clean = include_str!("fixtures/ckpt_parity_clean.rs");
    let out = lint_sources(&[("rust/src/rng/mod.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/ckpt_parity_pragma.rs");
    let out = lint_sources(&[("rust/src/rng/mod.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    // One pragma atom on the field line absorbs both findings, and is
    // therefore not stale.
    assert_eq!(out.suppressed.len(), 2, "{:#?}", out.suppressed);
}

#[test]
fn csv_schema_parity_fixture_triple() {
    let fire = include_str!("fixtures/csv_parity_fire.rs");
    let out = lint_sources(&[("rust/src/metrics/mod.rs", fire)]);
    // Membership (`loss` has no column), phantom column (`lost`) and
    // order divergence.
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::CsvSchemaParity));

    let clean = include_str!("fixtures/csv_parity_clean.rs");
    let out = lint_sources(&[("rust/src/metrics/mod.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/csv_parity_pragma.rs");
    let out = lint_sources(&[("rust/src/metrics/mod.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 3, "{:#?}", out.suppressed);
}

#[test]
fn config_surface_parity_fixture_triple() {
    let cfg = include_str!("fixtures/config_parity_cfg.rs");
    let cli_fire = include_str!("fixtures/config_parity_cli_fire.rs");
    let cli_clean = include_str!("fixtures/config_parity_cli_clean.rs");
    let cfg_pragma = include_str!("fixtures/config_parity_cfg_pragma.rs");

    let out = lint_sources(&[
        ("rust/src/config/mod.rs", cfg),
        ("rust/src/cli/mod.rs", cli_fire),
    ]);
    // `fresh` round-trips through JSON but has no CLI override arm;
    // the finding lands on the field in the config file.
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::ConfigSurfaceParity);
    assert_eq!(out.diagnostics[0].file, "rust/src/config/mod.rs");
    assert!(out.diagnostics[0].message.contains("CLI override arm"));

    let out = lint_sources(&[
        ("rust/src/config/mod.rs", cfg),
        ("rust/src/cli/mod.rs", cli_clean),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let out = lint_sources(&[
        ("rust/src/config/mod.rs", cfg_pragma),
        ("rust/src/cli/mod.rs", cli_fire),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
}

#[test]
fn campaign_spec_parity_fixture_triple() {
    let fire = include_str!("fixtures/campaign_parity_fire.rs");
    let out = lint_sources(&[("rust/src/fl/campaign/spec.rs", fire)]);
    // `tolerance` is emitted but has no JSON parse arm.
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::ConfigSurfaceParity);
    assert!(out.diagnostics[0].message.contains("`tolerance`"));
    assert!(out.diagnostics[0].message.contains("JSON parse arm"));

    let clean = include_str!("fixtures/campaign_parity_clean.rs");
    let out = lint_sources(&[("rust/src/fl/campaign/spec.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/campaign_parity_pragma.rs");
    let out = lint_sources(&[("rust/src/fl/campaign/spec.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
}

#[test]
fn stale_pragma_fixture_triple() {
    let fire = include_str!("fixtures/stale_pragma_fire.rs");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", fire)]);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::StalePragma);
    assert_eq!(out.diagnostics[0].line, 5, "finding lands on the pragma line");

    let clean = include_str!("fixtures/stale_pragma_clean.rs");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "the pragma still earns its keep");

    let pragma = include_str!("fixtures/stale_pragma_pragma.rs");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    // The dead unwrap pragma's stale finding is itself suppressed.
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
    assert_eq!(out.suppressed[0].rule, Rule::StalePragma);
}

// --------------------------------------------------- machine output

#[test]
fn json_output_schema_is_stable() {
    // Golden test: byte-exact schema v2 output.  If this fails because
    // the schema deliberately changed, bump report::VERSION and update
    // the golden (downstream --baseline files key on the version).
    let fire = include_str!("fixtures/stale_pragma_fire.rs");
    let report = lint_sources(&[("rust/src/fl/fixture.rs", fire)]);
    let expected = r#"{
  "version": 2,
  "files_scanned": 1,
  "findings": [
    {
      "rule": "stale-pragma",
      "file": "rust/src/fl/fixture.rs",
      "line": 5,
      "pragma": "none",
      "message": "lint:allow(unwrap-in-library) no longer suppresses anything on its attached code line — the guarded pattern is gone; delete the stale pragma",
      "snippet": "// lint:allow(unwrap-in-library): slice checked non-empty upstream.",
      "witness": []
    }
  ],
  "summary": {
    "violations": 1,
    "suppressed": 0,
    "suppressed_by_rule": {}
  }
}
"#;
    assert_eq!(render_json(&report), expected);
}

#[test]
fn baseline_tolerates_old_findings_but_fails_new_ones() {
    let fire = include_str!("fixtures/stale_pragma_fire.rs");
    let old = lint_sources(&[("rust/src/fl/fixture.rs", fire)]);
    let baseline = parse_baseline(&render_json(&old)).expect("own output parses");
    assert_eq!(baseline.len(), 1);

    // The identical tree is fully absorbed by its own baseline.
    assert!(new_findings(&old, &baseline).is_empty());

    // A pure line shift (new doc line up top) is still absorbed: the
    // baseline keys on (rule, file, snippet), not line numbers.
    let shifted = format!("//! moved\n{fire}");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", shifted.as_str())]);
    assert_eq!(out.diagnostics.len(), 1);
    assert!(new_findings(&out, &baseline).is_empty(), "line shifts are not new");

    // A genuinely new violation is not absorbed.
    let extra = "\npub fn second(v: &[f32]) -> f32 {\n    *v.first().unwrap()\n}\n";
    let grown = format!("{fire}{extra}");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", grown.as_str())]);
    assert_eq!(out.diagnostics.len(), 2, "{:#?}", out.diagnostics);
    let fresh = new_findings(&out, &baseline);
    assert_eq!(fresh.len(), 1, "only the unwrap is new");
    assert_eq!(fresh[0].rule, Rule::UnwrapInLibrary);
}

#[test]
fn diagnostics_are_line_sorted_and_formatted() {
    let src = include_str!("fixtures/unwrap_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    let mut lines: Vec<usize> = out.diagnostics.iter().map(|d| d.line).collect();
    let sorted = {
        let mut s = lines.clone();
        s.sort_unstable();
        s
    };
    assert_eq!(lines, sorted);
    lines.dedup();
    for d in &out.diagnostics {
        let rendered = d.to_string();
        assert!(
            rendered.starts_with(&format!("rust/src/fl/fixture.rs:{}:unwrap-in-library: ", d.line)),
            "{rendered}"
        );
    }
}

// ------------------------------------------- interprocedural rules
//
// Each fire fixture keeps the effect at least one call away from the
// root fn, so the local (PR-6) rules stay silent everywhere — only the
// call-graph taint connects root to effect, and the witness chain in
// the diagnostic proves the path it took.

#[test]
fn transitive_wall_clock_fixture_triple() {
    let root = include_str!("fixtures/transitive_wall_fire_root.rs");
    let leaf = include_str!("fixtures/transitive_wall_fire_leaf.rs");
    let out = lint_sources(&[
        ("rust/src/metrics/fixture.rs", root),
        ("rust/src/runtime/executor.rs", leaf),
    ]);
    // The Instant sits two calls deep in a wall-clock-allowlisted file,
    // so this is the only finding in the whole set.
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    let d = &out.diagnostics[0];
    assert_eq!(d.rule, Rule::TransitiveWallClock);
    assert_eq!(d.file, "rust/src/metrics/fixture.rs");
    assert_eq!(d.line, 6, "finding lands on the root fn's signature");
    let funcs: Vec<&str> = d.witness.iter().map(|h| h.func.as_str()).collect();
    assert_eq!(funcs, ["export_rounds", "stamp_all", "ticks"]);
    assert_eq!(d.witness[2].file, "rust/src/runtime/executor.rs");
    assert_eq!(d.witness[2].line, 9, "terminal hop is the Instant site");

    let clean_leaf = include_str!("fixtures/transitive_wall_clean_leaf.rs");
    let out = lint_sources(&[
        ("rust/src/metrics/fixture.rs", root),
        ("rust/src/runtime/executor.rs", clean_leaf),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/transitive_wall_pragma_root.rs");
    let out = lint_sources(&[
        ("rust/src/metrics/fixture.rs", pragma),
        ("rust/src/runtime/executor.rs", leaf),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
    assert_eq!(out.suppressed[0].rule, Rule::TransitiveWallClock);
    assert_eq!(suppressed_by_rule(&out), [("transitive-wall-clock", 1)]);
}

#[test]
fn panic_reachability_fixture_triple() {
    let root = include_str!("fixtures/panic_reach_fire_root.rs");
    let leaf = include_str!("fixtures/panic_reach_fire_leaf.rs");
    let out = lint_sources(&[
        ("rust/src/fl/fixture.rs", root),
        ("rust/src/data/fixture.rs", leaf),
    ]);
    // The unwrap lives in data/, outside unwrap-in-library's scope, so
    // only the reachability rule reports — once, at the pub entry fn.
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    let d = &out.diagnostics[0];
    assert_eq!(d.rule, Rule::PanicReachability);
    assert_eq!(d.file, "rust/src/fl/fixture.rs");
    assert_eq!(d.line, 5, "finding lands on the pub fn's signature");
    let funcs: Vec<&str> = d.witness.iter().map(|h| h.func.as_str()).collect();
    assert_eq!(funcs, ["api_mean", "pick_first"]);
    assert_eq!(d.witness[1].file, "rust/src/data/fixture.rs");
    assert_eq!(d.witness[1].line, 5, "terminal hop is the unwrap site");

    let clean_leaf = include_str!("fixtures/panic_reach_clean_leaf.rs");
    let out = lint_sources(&[
        ("rust/src/fl/fixture.rs", root),
        ("rust/src/data/fixture.rs", clean_leaf),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/panic_reach_pragma_root.rs");
    let out = lint_sources(&[
        ("rust/src/fl/fixture.rs", pragma),
        ("rust/src/data/fixture.rs", leaf),
    ]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
    assert_eq!(out.suppressed[0].rule, Rule::PanicReachability);
}

#[test]
fn pure_local_update_fixture_triple() {
    let fire = include_str!("fixtures/pure_update_fire.rs");
    let out = lint_sources(&[("rust/src/runtime/fixture.rs", fire)]);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    let d = &out.diagnostics[0];
    assert_eq!(d.rule, Rule::PureLocalUpdate);
    assert_eq!(d.line, 12, "finding lands on the impl's run signature");
    assert!(d.message.contains("rng-construction"), "{}", d.message);
    let funcs: Vec<&str> = d.witness.iter().map(|h| h.func.as_str()).collect();
    assert_eq!(funcs, ["Jittery::run", "jitter_seed"]);
    assert_eq!(d.witness[1].line, 18, "terminal hop is the RandomState site");

    let clean = include_str!("fixtures/pure_update_clean.rs");
    let out = lint_sources(&[("rust/src/runtime/fixture.rs", clean)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);

    let pragma = include_str!("fixtures/pure_update_pragma.rs");
    let out = lint_sources(&[("rust/src/runtime/fixture.rs", pragma)]);
    assert!(out.clean(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed.len(), 1, "{:#?}", out.suppressed);
    assert_eq!(out.suppressed[0].rule, Rule::PureLocalUpdate);
}

#[test]
fn unresolved_calls_surface_in_the_effects_artifact() {
    let src = include_str!("fixtures/unresolved_call.rs");
    let out = lint_sources(&[("rust/src/fl/fixture.rs", src)]);
    // Unknown callees never become findings — but they are not dropped
    // either: the artifact records them so reviewers can audit blind
    // spots in the taint analysis.
    assert!(out.clean(), "{:#?}", out.diagnostics);
    let calls: Vec<&str> = out.effects.unresolved.iter().map(|u| u.call.as_str()).collect();
    assert_eq!(calls, ["mystery_sink"]);
    assert_eq!(out.effects.unresolved[0].func, "relay");
    assert_eq!(out.effects.unresolved[0].line, 6);
    assert!(out.effects.render_json().contains("\"mystery_sink\""));
}

#[test]
fn witness_chain_round_trips_through_json() {
    let root = include_str!("fixtures/transitive_wall_fire_root.rs");
    let leaf = include_str!("fixtures/transitive_wall_fire_leaf.rs");
    let out = lint_sources(&[
        ("rust/src/metrics/fixture.rs", root),
        ("rust/src/runtime/executor.rs", leaf),
    ]);
    let json = render_json(&out);
    for hop in &out.diagnostics[0].witness {
        assert!(json.contains(&format!("\"fn\": \"{}\"", hop.func)), "{json}");
        assert!(json.contains(&format!("\"line\": {}", hop.line)), "{json}");
    }
    // And its own output is still baseline-parseable under schema v2.
    let baseline = parse_baseline(&json).expect("v2 output parses");
    assert_eq!(baseline.len(), 1);
    assert!(new_findings(&out, &baseline).is_empty());
}

#[test]
fn thread_count_never_changes_the_report() {
    let files: Vec<(&str, &str)> = vec![
        ("rust/src/metrics/fixture.rs", include_str!("fixtures/transitive_wall_fire_root.rs")),
        ("rust/src/runtime/executor.rs", include_str!("fixtures/transitive_wall_fire_leaf.rs")),
        ("rust/src/fl/fixture.rs", include_str!("fixtures/unwrap_fire.rs")),
        ("rust/src/data/fixture.rs", include_str!("fixtures/float_ordering_fire.rs")),
    ];
    std::env::set_var("EDGEFLOW_LINT_THREADS", "1");
    let single = render_json(&lint_sources(&files));
    std::env::set_var("EDGEFLOW_LINT_THREADS", "4");
    let multi = render_json(&lint_sources(&files));
    std::env::remove_var("EDGEFLOW_LINT_THREADS");
    assert_eq!(single, multi, "report must be byte-identical at any thread count");
    // Sanity: the set actually exercises both local and transitive
    // rules, so the identity above is not vacuous.
    assert!(single.contains("\"transitive-wall-clock\""));
    assert!(single.contains("\"unwrap-in-library\""));
}
