//! End-to-end tests for the rule engine over the fixture corpus.
//!
//! Fixtures live in `tests/fixtures/` (never compiled, never swept by
//! the tree gate) and are linted under *synthetic* repo-relative
//! paths so each test exercises the scope table on purpose.

use edgeflow_lint::{lint_source, Rule};

fn rules_of(rel: &str, src: &str) -> Vec<Rule> {
    lint_source(rel, src).diagnostics.iter().map(|d| d.rule).collect()
}

#[test]
fn float_ordering_fires_on_partial_cmp_and_float_eq() {
    let src = include_str!("fixtures/float_ordering_fire.rs");
    // data/ is outside the unwrap scope, so only float-ordering fires.
    let out = lint_source("rust/src/data/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 2, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::FloatOrdering));
    let lines: Vec<usize> = out.diagnostics.iter().map(|d| d.line).collect();
    assert_eq!(lines, vec![5, 9]);
    assert!(out
        .diagnostics
        .iter()
        .any(|d| d.to_string().starts_with("rust/src/data/fixture.rs:5:float-ordering:")));
}

#[test]
fn float_ordering_clean_on_total_cmp_and_test_oracles() {
    let src = include_str!("fixtures/float_ordering_clean.rs");
    let out = lint_source("rust/src/data/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    // The same float == in a non-test position would fire: strip the
    // cfg(test) attribute and the oracle is no longer exempt.
    let stripped = src.replace("#[cfg(test)]", "");
    let out = lint_source("rust/src/data/fixture.rs", &stripped);
    assert!(!out.diagnostics.is_empty());
}

#[test]
fn wall_clock_fires_in_sim_modules_only() {
    let src = include_str!("fixtures/wall_clock_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    // Two tokens per line on the use, the signature and the body.
    assert_eq!(out.diagnostics.len(), 6, "{:#?}", out.diagnostics);
    assert!(out.diagnostics.iter().all(|d| d.rule == Rule::WallClockInSim));

    // Scope table: allowlisted modules stay silent on identical code.
    for quiet in [
        "rust/src/bench/fixture.rs",
        "rust/src/util/timer.rs",
        "rust/src/runtime/executor.rs",
        "rust/benches/bench_parallel.rs",
    ] {
        let out = lint_source(quiet, src);
        assert!(out.diagnostics.is_empty(), "{quiet}: {:#?}", out.diagnostics);
    }
}

#[test]
fn unordered_fires_in_determinism_critical_modules_only() {
    let fire = include_str!("fixtures/unordered_fire.rs");
    let out = lint_source("rust/src/fl/aggregate.rs", fire);
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out
        .diagnostics
        .iter()
        .all(|d| d.rule == Rule::UnorderedIteration));
    // Outside the scoped modules the same code is fine.
    assert!(rules_of("rust/src/topology/graph.rs", fire).is_empty());

    let clean = include_str!("fixtures/unordered_clean.rs");
    assert!(rules_of("rust/src/fl/aggregate.rs", clean).is_empty());
}

#[test]
fn unwrap_fires_in_library_code_not_tests() {
    let src = include_str!("fixtures/unwrap_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 3, "{:#?}", out.diagnostics);
    assert!(out
        .diagnostics
        .iter()
        .all(|d| d.rule == Rule::UnwrapInLibrary));
    // Whole-file test trees are exempt.
    assert!(rules_of("rust/tests/integration.rs", src).is_empty());
    // Outside fl/ and runtime/ the rule does not apply.
    assert!(rules_of("rust/src/cli/mod.rs", src).is_empty());
}

#[test]
fn justified_pragma_suppresses_and_counts() {
    let src = include_str!("fixtures/unwrap_pragma.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    assert_eq!(out.suppressed, 1);
}

#[test]
fn pragma_without_reason_is_rejected_and_does_not_suppress() {
    let src = include_str!("fixtures/unwrap_pragma_bad.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    let rules = rules_of("rust/src/fl/fixture.rs", src);
    assert_eq!(rules, vec![Rule::Pragma, Rule::UnwrapInLibrary]);
    assert_eq!(out.suppressed, 0);
}

#[test]
fn pragma_attachment_breaks_at_blank_lines() {
    let src = "\
pub fn f(v: &[f32]) -> f32 {\n\
    // lint:allow(unwrap-in-library): blank line below detaches this.\n\
\n\
    *v.first().unwrap()\n\
}\n";
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::UnwrapInLibrary);
    assert_eq!(out.suppressed, 0);
}

#[test]
fn pragma_with_unknown_rule_is_flagged() {
    let src = "// lint:allow(no-such-rule): reasons\npub fn f() {}\n";
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert_eq!(out.diagnostics.len(), 1);
    assert_eq!(out.diagnostics[0].rule, Rule::Pragma);
    assert!(out.diagnostics[0].message.contains("no-such-rule"));
}

#[test]
fn unsafe_requires_safety_comment() {
    let fire = include_str!("fixtures/unsafe_fire.rs");
    let out = lint_source("rust/src/data/fixture.rs", fire);
    assert_eq!(out.diagnostics.len(), 1, "{:#?}", out.diagnostics);
    assert_eq!(out.diagnostics[0].rule, Rule::UnsafeAudit);

    let ok = include_str!("fixtures/unsafe_safety_ok.rs");
    let out = lint_source("rust/src/data/fixture.rs", ok);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
}

#[test]
fn tokenizer_tricky_file_is_silent() {
    let src = include_str!("fixtures/tokenizer_tricky.rs");
    // Lint under the most aggressive scope combination: fl/ paths get
    // float-ordering, wall-clock, unwrap and unsafe all enabled.
    let out = lint_source("rust/src/fl/fixture.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
    let out = lint_source("rust/src/fl/aggregate.rs", src);
    assert!(out.diagnostics.is_empty(), "{:#?}", out.diagnostics);
}

#[test]
fn diagnostics_are_line_sorted_and_formatted() {
    let src = include_str!("fixtures/unwrap_fire.rs");
    let out = lint_source("rust/src/fl/fixture.rs", src);
    let mut lines: Vec<usize> = out.diagnostics.iter().map(|d| d.line).collect();
    let sorted = {
        let mut s = lines.clone();
        s.sort_unstable();
        s
    };
    assert_eq!(lines, sorted);
    lines.dedup();
    for d in &out.diagnostics {
        let rendered = d.to_string();
        assert!(
            rendered.starts_with(&format!("rust/src/fl/fixture.rs:{}:unwrap-in-library: ", d.line)),
            "{rendered}"
        );
    }
}
