// Fixture: a pragma without a reason is rejected — it emits a pragma
// diagnostic AND fails to suppress the underlying violation.

pub fn first(v: &[f32]) -> f32 {
    // lint:allow(unwrap-in-library)
    *v.first().unwrap()
}
