//! stale-pragma pragma fixture (linted as rust/src/fl/fixture.rs): a
//! dead pragma deliberately kept, itself excused by a stale-pragma
//! allow attached to the same code line.

pub fn first(v: &[f32]) -> f32 {
    // lint:allow(unwrap-in-library): slice checked non-empty upstream.
    // lint:allow(stale-pragma): kept while the compat branch still
    // backports unwrap-based code onto this line.
    v[0]
}
