//! config-surface-parity campaign fixture (linted as
//! rust/src/fl/campaign/spec.rs): `tolerance` is emitted but never
//! parsed back — a spec field a round-trip would silently drop.

pub struct CampaignSpec {
    pub name: String,
    pub seed: u64,
    pub tolerance: f64,
}

impl CampaignSpec {
    pub fn to_json(&self) -> String {
        emit(
            pair("name", &self.name),
            pair("seed", self.seed),
            pair("tolerance", self.tolerance),
        )
    }

    pub fn from_json(s: &str) -> CampaignSpec {
        with_defaults(read(s, "name"), read(s, "seed"))
    }
}
