//! config-surface-parity config-side fixture (linted as
//! rust/src/config/mod.rs): both fields round-trip through the JSON
//! surfaces; whether the CLI arm exists is the companion fixture's
//! business.

pub struct ExperimentConfig {
    pub rounds: usize,
    pub fresh: f64,
}

impl ExperimentConfig {
    pub fn to_json(&self) -> String {
        emit(pair("rounds", self.rounds), pair("fresh", self.fresh))
    }

    pub fn from_json(s: &str) -> ExperimentConfig {
        ExperimentConfig { rounds: read(s, "rounds"), fresh: read(s, "fresh") }
    }
}
