// Fixture: the panic leaf, linted as rust/src/data/fixture.rs where
// unwrap-in-library does not apply.

pub fn pick_first(v: &[f32]) -> f32 {
    *v.first().unwrap()
}
