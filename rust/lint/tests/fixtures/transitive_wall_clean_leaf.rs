// Fixture: same call shape as transitive_wall_fire_leaf.rs but the
// leaf never reads the clock — the whole chain is clean.

pub fn stamp_all() -> u64 {
    ticks()
}

fn ticks() -> u64 {
    7
}
