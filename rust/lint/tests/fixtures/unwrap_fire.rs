// Fixture: unwrap/expect/panic in library code, no pragma.

pub fn first(v: &[f32]) -> f32 {
    *v.first().unwrap()
}

pub fn last(v: &[f32]) -> f32 {
    *v.last().expect("non-empty")
}

pub fn boom() {
    panic!("unconditional");
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::first(&[1.0]).to_bits(), 1.0f32.to_bits());
        let x: Option<u32> = Some(3);
        assert_eq!(x.unwrap(), 3);
    }
}
