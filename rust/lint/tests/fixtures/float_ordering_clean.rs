// Fixture: total_cmp orderings and float equality confined to a test
// oracle must not fire.

pub fn sort_desc(v: &mut Vec<f32>) {
    v.sort_by(|a, b| b.total_cmp(a));
}

#[cfg(test)]
mod tests {
    #[test]
    fn oracle() {
        let x = 1.0f64;
        assert!(x == 1.0);
        assert!(x != 2.0);
    }
}
