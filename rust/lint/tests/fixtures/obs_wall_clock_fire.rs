// Fixture: raw wall-clock reads in the obs core.  Linted under
// rust/src/obs/mod.rs this fires three times; under the allowlisted
// rust/src/obs/wallclock.rs the scope table keeps it silent.

use std::time::Instant;

pub fn mark() -> Instant {
    Instant::now()
}
