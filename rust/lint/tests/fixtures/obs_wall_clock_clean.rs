// Fixture: the obs core handling opaque marks only — no clock reads,
// no pragmas needed.  Clean under any rust/src/obs/ path.

pub struct Mark(u64);

pub fn rel_ns(epoch_ns: u64, mark: &Mark) -> u64 {
    mark.0.saturating_sub(epoch_ns)
}
