//! config-surface-parity campaign fixture (linted as
//! rust/src/fl/campaign/spec.rs): every spec field appears in both the
//! JSON emit and the JSON parse fn — the contract's happy path.

pub struct CampaignSpec {
    pub name: String,
    pub seed: u64,
    pub tolerance: f64,
}

impl CampaignSpec {
    pub fn to_json(&self) -> String {
        emit(
            pair("name", &self.name),
            pair("seed", self.seed),
            pair("tolerance", self.tolerance),
        )
    }

    pub fn from_json(s: &str) -> CampaignSpec {
        CampaignSpec {
            name: read(s, "name"),
            seed: read(s, "seed"),
            tolerance: read(s, "tolerance"),
        }
    }
}
