// Fixture: interprocedural wall-clock root (linted as
// rust/src/metrics/fixture.rs).  The clock read sits two calls away
// in a locally-allowlisted file, so no local rule fires anywhere —
// only transitive-wall-clock can see it.

pub fn export_rounds() -> u64 {
    stamp_all()
}
