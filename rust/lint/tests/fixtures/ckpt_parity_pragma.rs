//! checkpoint-parity pragma fixture (linted as rust/src/rng/mod.rs):
//! the same drift as the fire fixture, but justified — one pragma on
//! the field line covers both the encode and the decode finding.

pub struct RngState {
    pub seed: u64,
    // lint:allow(checkpoint-parity): `stream` is re-derived from the
    // seed on restore and deliberately skips serialization.
    pub stream: u64,
}

impl RngState {
    pub fn to_json(&self) -> String {
        emit_u64("seed", self.seed)
    }

    pub fn from_json(s: &str) -> RngState {
        with_defaults(read_u64(s, "seed"))
    }
}
