// Fixture: a LocalUpdateHandle::run impl that reaches an
// entropy-seeded RNG through a helper — pure-local-update fires with
// a witness chain; no local rule knows about RNG construction.

pub trait LocalUpdateHandle {
    fn run(&self) -> u32;
}

pub struct Jittery;

impl LocalUpdateHandle for Jittery {
    fn run(&self) -> u32 {
        jitter_seed()
    }
}

fn jitter_seed() -> u32 {
    let state = std::collections::hash_map::RandomState::new();
    hash_of(&state)
}

fn hash_of(_s: &std::collections::hash_map::RandomState) -> u32 {
    0
}
