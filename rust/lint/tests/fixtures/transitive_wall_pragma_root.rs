// Fixture: the fire root with a justified grant at the root fn's
// signature line (the other suppression point is the seed site).

// lint:allow(transitive-wall-clock): export timing is log-only here;
// the exported rows carry simulated time from NetSim.
pub fn export_rounds() -> u64 {
    stamp_all()
}
