// Fixture: the fire root with a justified grant on the public fn.

// lint:allow(panic-reachability): callers pass compile-time non-empty
// batches; the reachable unwrap is unreachable in practice.
pub fn api_mean(v: &[f32]) -> f32 {
    pick_first(v)
}
