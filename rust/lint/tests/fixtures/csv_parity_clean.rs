//! csv-schema-parity clean fixture (linted as rust/src/metrics/mod.rs):
//! header, record fields and row-encoder order all agree.

pub struct RoundRecord {
    pub round: usize,
    pub loss: f64,
}

pub const METRICS_CSV_HEADER: &str = "round loss";

impl RoundRecord {
    pub fn to_ckpt_json(&self) -> String {
        pair(self.round, self.loss)
    }

    pub fn from_ckpt_json(s: &str) -> RoundRecord {
        RoundRecord { round: read(s, "round"), loss: read(s, "loss") }
    }

    pub fn csv_fields(&self) -> Vec<String> {
        vec![num(self.round), num(self.loss)]
    }
}
