//! stale-pragma clean fixture (linted as rust/src/fl/fixture.rs): the
//! pragma still suppresses a live finding, so it is not stale.

pub fn first(v: &[f32]) -> f32 {
    // lint:allow(unwrap-in-library): slice checked non-empty upstream.
    *v.first().unwrap()
}
