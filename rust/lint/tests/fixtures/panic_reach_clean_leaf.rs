// Fixture: the leaf made total — no panic site anywhere on the chain.

pub fn pick_first(v: &[f32]) -> f32 {
    v.first().copied().unwrap_or(0.0)
}
