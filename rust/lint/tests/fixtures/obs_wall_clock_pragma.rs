// Fixture: a justified wall-clock grant inside the obs core.  The
// pragma suppresses exactly one finding and is therefore not stale.

pub struct Mark {
    // lint:allow(wall-clock-in-sim): opaque wall-clock mark storage; only wallclock.rs reads the clock.
    pub at: std::time::Instant,
}
