//! config-surface-parity CLI-side fire fixture (linted as
//! rust/src/cli/mod.rs): `rounds` is wired through, `fresh` is not.

pub fn apply_overrides(mut cfg: ExperimentConfig, a: &Args) -> ExperimentConfig {
    if let Some(v) = a.get("rounds") {
        cfg.rounds = v;
    }
    cfg
}
