//! config-surface-parity CLI-side clean fixture (linted as
//! rust/src/cli/mod.rs): every config field has an override arm.

pub fn apply_overrides(mut cfg: ExperimentConfig, a: &Args) -> ExperimentConfig {
    if let Some(v) = a.get("rounds") {
        cfg.rounds = v;
    }
    if let Some(v) = a.get("fresh") {
        cfg.fresh = v;
    }
    cfg
}
