//! checkpoint-parity fire fixture (linted as rust/src/rng/mod.rs):
//! `stream` never reaches the encoder or the decoder, so a resumed
//! run would silently reset it.

pub struct RngState {
    pub seed: u64,
    pub stream: u64,
}

impl RngState {
    pub fn to_json(&self) -> String {
        emit_u64("seed", self.seed)
    }

    pub fn from_json(s: &str) -> RngState {
        with_defaults(read_u64(s, "seed"))
    }
}
