// Fixture: a call the resolver cannot map to any in-tree fn — it must
// land in the effects artifact's unresolved list, and no rule may
// invent a finding for it.

pub fn relay(v: &[f32]) -> f32 {
    mystery_sink(v)
}
