// Fixture: unsafe with a SAFETY: comment, same-line and block-above.

pub fn read_first(v: &[f32]) -> f32 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees the pointer reads in-bounds
    // element zero of a live slice.
    unsafe { *v.as_ptr() }
}

pub fn read_second(v: &[f32]) -> f32 {
    assert!(v.len() > 1);
    unsafe { *v.as_ptr().add(1) } // SAFETY: length checked above.
}
