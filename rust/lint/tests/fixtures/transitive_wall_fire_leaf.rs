// Fixture: the wall-clock leaf, linted as rust/src/runtime/executor.rs
// (allowlisted for the local rule — wall-clock-in-sim stays silent).

pub fn stamp_all() -> u64 {
    ticks()
}

fn ticks() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
