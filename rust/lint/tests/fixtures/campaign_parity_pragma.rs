//! config-surface-parity campaign fixture (linted as
//! rust/src/fl/campaign/spec.rs): the same parse-side gap as the fire
//! fixture, but justified on the field line.

pub struct CampaignSpec {
    pub name: String,
    pub seed: u64,
    // lint:allow(config-surface-parity): `tolerance` is derived from
    // the CLI flag on load in this hypothetical and never read back.
    pub tolerance: f64,
}

impl CampaignSpec {
    pub fn to_json(&self) -> String {
        emit(
            pair("name", &self.name),
            pair("seed", self.seed),
            pair("tolerance", self.tolerance),
        )
    }

    pub fn from_json(s: &str) -> CampaignSpec {
        with_defaults(read(s, "name"), read(s, "seed"))
    }
}
