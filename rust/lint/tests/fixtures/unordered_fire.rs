// Fixture: unordered containers in a determinism-critical module.

use std::collections::HashMap;

pub fn tally(keys: &[u32]) -> HashMap<u32, usize> {
    let mut m = HashMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m
}
