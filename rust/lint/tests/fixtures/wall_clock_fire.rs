// Fixture: wall-clock types in a simulated-time module.  Linted under
// a rust/src/fl/ path this fires twice; under rust/src/bench/ the
// scope table keeps it silent.

use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    (Instant::now(), SystemTime::now())
}
