// Fixture: unsafe without a SAFETY: comment.

pub fn read_first(v: &[f32]) -> f32 {
    unsafe { *v.as_ptr() }
}
