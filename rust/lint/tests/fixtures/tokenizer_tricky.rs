// Fixture: every needle appears only inside strings, char literals or
// comments — the lint must stay silent on this file.
//
// prose mentions: Instant::now(), SystemTime, HashMap, HashSet,
// a.partial_cmp(&b).unwrap(), panic!("x"), x == 0.0
/* block comment: unsafe { SystemTime::now() }.expect("never") */

pub const PLAIN: &str = "Instant SystemTime HashMap .partial_cmp .unwrap() panic! == 0.0";
pub const RAW: &str = r#"unsafe { x.expect("msg") } and "quoted" HashSet"#;
pub const BYTES: &[u8] = b"SystemTime .unwrap() panic!";
pub const ESCAPED: &str = "esc \" unsafe .partial_cmp \\";
pub const MULTI: &str = "line one
  .partial_cmp line two == 0.0";

pub fn lifetime_not_char<'a>(x: &'a f64) -> &'a f64 {
    let _q = '"';
    let _division = 4 / 2 / 1;
    x
}
