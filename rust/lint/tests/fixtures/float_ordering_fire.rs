// Fixture: float-ordering must fire on partial_cmp and on exact
// float equality outside test code.

pub fn sort_desc(v: &mut Vec<f32>) {
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
}

pub fn is_zero(x: f64) -> bool {
    x == 0.0
}
