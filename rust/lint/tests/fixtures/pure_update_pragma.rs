// Fixture: the fire impl with a justified grant at the impl's run
// signature.

pub trait LocalUpdateHandle {
    fn run(&self) -> u32;
}

pub struct Jittery;

impl LocalUpdateHandle for Jittery {
    // lint:allow(pure-local-update): ablation-only handle, never used
    // in replayed migrations; the jitter models stragglers.
    fn run(&self) -> u32 {
        jitter_seed()
    }
}

fn jitter_seed() -> u32 {
    let state = std::collections::hash_map::RandomState::new();
    hash_of(&state)
}

fn hash_of(_s: &std::collections::hash_map::RandomState) -> u32 {
    0
}
