// Fixture: justified pragmas suppress, both same-line and from the
// comment block directly above.

pub fn first(v: &[f32]) -> f32 {
    assert!(!v.is_empty());
    // lint:allow(unwrap-in-library): asserted non-empty on the line above.
    *v.first().unwrap()
}

pub fn mean(v: &[f32]) -> f32 {
    let n = v.len().max(1) as f32;
    v.iter().sum::<f32>() / n // lint:allow(unwrap-in-library): no unwrap here, pragma is inert but valid.
}
