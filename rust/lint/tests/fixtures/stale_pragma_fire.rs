//! stale-pragma fire fixture (linted as rust/src/fl/fixture.rs): the
//! unwrap this pragma once guarded is long gone.

pub fn first(v: &[f32]) -> f32 {
    // lint:allow(unwrap-in-library): slice checked non-empty upstream.
    v[0]
}
