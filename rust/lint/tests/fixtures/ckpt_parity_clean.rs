//! checkpoint-parity clean fixture (linted as rust/src/rng/mod.rs):
//! every field round-trips.  On the encode side `stream` only appears
//! as a serialized string key — the string-literal view must count.

pub struct RngState {
    pub seed: u64,
    pub stream: u64,
}

impl RngState {
    pub fn to_json(&self) -> String {
        join(emit_u64("seed", self.seed), emit_u64("stream", self.stream_id()))
    }

    pub fn from_json(s: &str) -> RngState {
        RngState { seed: read_u64(s, "seed"), stream: read_u64(s, "stream") }
    }
}
