//! config-surface-parity pragma fixture (linted as
//! rust/src/config/mod.rs): `fresh` has no CLI arm on purpose.

pub struct ExperimentConfig {
    pub rounds: usize,
    // lint:allow(config-surface-parity): `fresh` is an internal tuning
    // knob set by presets only — no CLI flag by design.
    pub fresh: f64,
}

impl ExperimentConfig {
    pub fn to_json(&self) -> String {
        emit(pair("rounds", self.rounds), pair("fresh", self.fresh))
    }

    pub fn from_json(s: &str) -> ExperimentConfig {
        ExperimentConfig { rounds: read(s, "rounds"), fresh: read(s, "fresh") }
    }
}
