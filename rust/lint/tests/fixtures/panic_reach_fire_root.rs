// Fixture: panic-reachability root (linted as rust/src/fl/fixture.rs).
// The unwrap lives one call away in data/, outside the local unwrap
// rule's scope — only the transitive rule connects them.

pub fn api_mean(v: &[f32]) -> f32 {
    pick_first(v)
}
