//! csv-schema-parity pragma fixture (linted as rust/src/metrics/mod.rs):
//! the `lost`/`loss` mismatch is kept for archived-run compatibility;
//! the field-line pragma covers the membership finding and the
//! header-line pragma covers the phantom-column and order findings.

pub struct RoundRecord {
    pub round: usize,
    // lint:allow(csv-schema-parity): the export spells this column
    // `lost` for backwards compatibility with archived runs.
    pub loss: f64,
}

// lint:allow(csv-schema-parity): see the field note — legacy spelling.
pub const METRICS_CSV_HEADER: &str = "round lost";

impl RoundRecord {
    pub fn to_ckpt_json(&self) -> String {
        pair(self.round, self.loss)
    }

    pub fn from_ckpt_json(s: &str) -> RoundRecord {
        RoundRecord { round: read(s, "round"), loss: read(s, "loss") }
    }

    pub fn csv_fields(&self) -> Vec<String> {
        vec![num(self.round), num(self.loss)]
    }
}
