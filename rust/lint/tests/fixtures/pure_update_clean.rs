// Fixture: a pure LocalUpdateHandle::run impl — deterministic helper
// chain, no effects at any depth.

pub trait LocalUpdateHandle {
    fn run(&self) -> u32;
}

pub struct Sgd;

impl LocalUpdateHandle for Sgd {
    fn run(&self) -> u32 {
        step(41)
    }
}

fn step(x: u32) -> u32 {
    x + 1
}
