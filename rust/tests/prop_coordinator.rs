//! Property tests over the coordinator invariants (DESIGN.md §7), using
//! the in-tree seeded property harness (`edgeflow::testing::prop`).

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, ExperimentConfig, StragglerPolicy,
    TopologyKind,
};
use edgeflow::data::partition::build_federation;
use edgeflow::fl::aggregate::{mean_into, weighted_mean_into};
use edgeflow::fl::scheduler::ClusterSchedule;
use edgeflow::fl::strategy::Strategy;
use edgeflow::netsim::NetSim;
use edgeflow::testing::prop::forall;
use edgeflow::topology::accounting::CommAccountant;
use edgeflow::topology::builder::{build, TopologyParams};
use edgeflow::topology::route::RouteTable;
use edgeflow::util::json::Json;

fn random_distribution(g: &mut edgeflow::testing::prop::Gen) -> Distribution {
    match g.int(0, 3) {
        0 => Distribution::Iid,
        1 => Distribution::NiidA,
        2 => Distribution::NiidB,
        // whole percents: the serialized form ("noniid95") is
        // percent-granular by contract
        _ => Distribution::NonIid { major_fraction: g.int(50, 100) as f64 / 100.0 },
    }
}

#[test]
fn prop_partition_exactly_once() {
    forall("partition-exactly-once", 25, |g| {
        let clusters = g.int(1, 8);
        let clients = clusters * g.int(1, 6);
        let spc = g.int(10, 80);
        let dist = random_distribution(g);
        let fed = build_federation(
            DatasetKind::SynthFashion,
            &dist,
            clients,
            clusters,
            spc,
            10,
            g.int(0, 1 << 20) as u64,
        )
        .map_err(|e| e.to_string())?;
        let mut seen = vec![false; fed.train.len()];
        for c in &fed.clients {
            if c.samples.len() != spc {
                return Err(format!("client {} has {} samples", c.id, c.samples.len()));
            }
            for &i in &c.samples {
                if seen[i] {
                    return Err(format!("sample {i} assigned twice"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("orphan samples".into());
        }
        // quotas match labels
        for c in &fed.clients {
            if c.histogram(&fed.train) != c.quotas {
                return Err(format!("client {} histogram != quotas", c.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_noniid_major_fraction_respected() {
    forall("noniid-major-fraction", 25, |g| {
        let x = g.f64(0.5, 1.0);
        let spc = g.int(20, 100);
        let fed = build_federation(
            DatasetKind::SynthFashion,
            &Distribution::NonIid { major_fraction: x },
            8,
            2,
            spc,
            10,
            g.int(0, 9999) as u64,
        )
        .map_err(|e| e.to_string())?;
        for c in &fed.clients {
            let mut sorted = c.quotas.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            let top2: usize = sorted[..2].iter().sum();
            let want = (x * spc as f64).round() as usize;
            if top2 + 1 < want {
                return Err(format!(
                    "client {}: top-2 {top2} < expected major {want}",
                    c.id
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topology_fully_routable_and_symmetric() {
    forall("topology-routable", 20, |g| {
        let kind = *g.choose(&TopologyKind::ALL);
        let clusters = g.int(1, 12);
        let cpc = g.int(1, 6);
        let topo =
            build(&TopologyParams::new(kind, clusters, cpc)).map_err(|e| e.to_string())?;
        let rt = RouteTable::hops(&topo);
        let cloud = topo.cloud().map_err(|e| e.to_string())?;
        for c in topo.clients() {
            if rt.dist(c, cloud).is_none() {
                return Err(format!("{kind:?}: client {c:?} cannot reach cloud"));
            }
        }
        let bs = topo.base_stations();
        for (i, &a) in bs.iter().enumerate() {
            for &b in &bs[i + 1..] {
                let ab = rt.dist(a, b);
                let ba = rt.dist(b, a);
                if ab.is_none() || ab != ba {
                    return Err(format!("{kind:?}: asymmetric {a:?}<->{b:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_accounting_conserves_bytes() {
    forall("accounting-conservation", 20, |g| {
        let kind = *g.choose(&TopologyKind::ALL);
        let topo = build(&TopologyParams::new(kind, g.int(2, 8), 2))
            .map_err(|e| e.to_string())?;
        let rt = RouteTable::hops(&topo);
        let mut acc = CommAccountant::new();
        let nodes: Vec<_> = topo.clients();
        let mut rng = g.rng();
        for round in 0..g.int(1, 30) {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            if a == b {
                continue;
            }
            acc.record(&topo, &rt, a, b, rng.below(10_000) as u64 + 1, "t", round)
                .map_err(|e| e.to_string())?;
        }
        if !acc.conserves_bytes() {
            return Err("link sum != byte-hops".into());
        }
        Ok(())
    });
}

#[test]
fn prop_aggregation_permutation_invariant_and_convex() {
    forall("aggregation-invariants", 30, |g| {
        let n = g.int(2, 8);
        let len = g.int(1, 400);
        let mut rng = g.rng();
        let sources: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.f32() * 8.0 - 4.0).collect())
            .collect();
        let refs: Vec<&[f32]> = sources.iter().map(|v| v.as_slice()).collect();
        let mut fwd = vec![0f32; len];
        mean_into(&mut fwd, &refs);
        // permutation invariance
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let prefs: Vec<&[f32]> = perm.iter().map(|&i| refs[i]).collect();
        let mut rev = vec![0f32; len];
        mean_into(&mut rev, &prefs);
        for (a, b) in fwd.iter().zip(&rev) {
            if (a - b).abs() > 1e-4 {
                return Err(format!("permutation changed mean: {a} vs {b}"));
            }
        }
        // convexity envelope under random weights
        let w: Vec<f64> = (0..n).map(|_| rng.f64() + 1e-3).collect();
        let mut wm = vec![0f32; len];
        weighted_mean_into(&mut wm, &refs, &w);
        for j in 0..len {
            let lo = sources.iter().map(|s| s[j]).fold(f32::INFINITY, f32::min);
            let hi = sources.iter().map(|s| s[j]).fold(f32::NEG_INFINITY, f32::max);
            if wm[j] < lo - 1e-4 || wm[j] > hi + 1e-4 {
                return Err(format!("component {j} out of envelope"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sequential_schedule_covers_all_clusters() {
    forall("schedule-coverage", 20, |g| {
        let m = g.int(1, 16);
        let mut s = ClusterSchedule::sequential(m);
        let mut seen = vec![false; m];
        for t in 0..m {
            seen[s.next(t)] = true;
        }
        if !seen.iter().all(|&b| b) {
            return Err(format!("{m} clusters not covered in {m} rounds"));
        }
        Ok(())
    });
}

#[test]
fn prop_random_schedule_frequency_converges() {
    forall("schedule-frequency", 10, |g| {
        let m = g.int(2, 10);
        let mut s = ClusterSchedule::random(m, g.int(0, 1 << 30) as u64);
        let rounds = 3000;
        let mut counts = vec![0usize; m];
        for t in 0..rounds {
            counts[s.next(t)] += 1;
        }
        let expect = rounds as f64 / m as f64;
        for (i, &c) in counts.iter().enumerate() {
            if (c as f64) < expect * 0.6 || (c as f64) > expect * 1.4 {
                return Err(format!("cluster {i} frequency {c} vs expected {expect}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_netsim_delivers_everything_monotonically() {
    forall("netsim-delivery", 15, |g| {
        let kind = *g.choose(&TopologyKind::ALL);
        let topo = build(&TopologyParams::new(kind, g.int(2, 6), 2))
            .map_err(|e| e.to_string())?;
        let rt = RouteTable::latency(&topo);
        let mut sim = NetSim::new(&topo);
        let nodes = topo.clients();
        let mut rng = g.rng();
        let n = g.int(1, 60);
        for i in 0..n {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            sim.submit(&rt, a, b, rng.below(1_000_000) as u64, i as f64 * 0.01)
                .map_err(|e| e.to_string())?;
        }
        let out = sim.run();
        if out.len() != n {
            return Err(format!("{} of {n} transfers delivered", out.len()));
        }
        for o in &out {
            if o.delivered_s < o.submitted_s {
                return Err("delivered before submitted".into());
            }
            if o.queue_wait_s < 0.0 {
                return Err("negative queue wait".into());
            }
        }
        // completion order sorted
        for w in out.windows(2) {
            if w[0].delivered_s > w[1].delivered_s {
                return Err("completion order not sorted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fedavg_sampling_without_replacement() {
    forall("fedavg-sampling", 20, |g| {
        let clusters = g.int(1, 5);
        let clients = clusters * g.int(2, 8);
        let fed = build_federation(
            DatasetKind::SynthFashion,
            &Distribution::Iid,
            clients,
            clusters,
            20,
            10,
            g.int(0, 999) as u64,
        )
        .map_err(|e| e.to_string())?;
        let cfg = ExperimentConfig {
            algorithm: Algorithm::FedAvg,
            clients,
            clusters,
            samples_per_client: 20,
            batch_size: 8,
            seed: g.int(0, 999) as u64,
            ..ExperimentConfig::default()
        };
        let topo = build(&TopologyParams::new(TopologyKind::Simple, clusters, clients / clusters))
            .map_err(|e| e.to_string())?;
        let mut s = Strategy::for_config(&cfg, &fed, &topo, 40_000);
        for t in 0..10 {
            let p = s.plan_round(t, &fed, None);
            let mut ids = p.participants();
            let n = ids.len();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != n {
                return Err(format!("round {t}: duplicate participants"));
            }
            if ids.iter().any(|&i| i >= clients) {
                return Err("participant out of range".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_config_json_roundtrip() {
    forall("config-json-roundtrip", 30, |g| {
        let clusters = g.int(1, 10);
        let cfg = ExperimentConfig {
            name: format!("p{}", g.int(0, 100)),
            algorithm: *g.choose(&Algorithm::ALL),
            dataset: *g.choose(&[DatasetKind::SynthFashion, DatasetKind::SynthCifar]),
            distribution: random_distribution(g),
            topology: *g.choose(&TopologyKind::ALL),
            clients: clusters * g.int(1, 10),
            clusters,
            local_steps: g.int(1, 10),
            rounds: g.int(1, 100),
            batch_size: g.int(1, 64),
            lr: g.f64(1e-5, 0.5),
            optimizer: if g.bool() { "sgd".into() } else { "adam".into() },
            model: "fashion_mlp".into(),
            samples_per_client: 64 + g.int(0, 100),
            test_samples: g.int(10, 500),
            eval_every: g.int(0, 10),
            seed: g.int(0, 1 << 30) as u64,
            workers: g.int(0, 8),
            dropout: g.int(0, 99) as f64 / 100.0,
            deadline_s: g.int(0, 50) as f64 / 10.0,
            straggler_policy: if g.bool() {
                StragglerPolicy::Defer
            } else {
                StragglerPolicy::Drop
            },
        };
        let cfg = cfg.validate().map_err(|e| e.to_string())?;
        let text = cfg.to_json().pretty();
        let back = ExperimentConfig::from_json(
            &Json::parse(&text).map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        if back.algorithm != cfg.algorithm
            || back.distribution != cfg.distribution
            || back.clients != cfg.clients
            || back.lr != cfg.lr
            || back.seed != cfg.seed
            || back.straggler_policy != cfg.straggler_policy
        {
            return Err("round-trip mismatch".into());
        }
        Ok(())
    });
}
