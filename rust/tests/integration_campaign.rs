//! End-to-end tests for the `fl::campaign` subsystem on the native
//! engine (zero artifacts): grid expansion from a spec file, the
//! nested-parallelism budget split's report bit-identity, journal-based
//! resume byte-identity, the `--baseline` regression semantics, and the
//! `BENCH_campaign.json` trajectory accumulation.

use std::path::PathBuf;

use edgeflow::config::Algorithm;
use edgeflow::fl::campaign::{
    append_bench, parse_baseline, regressions, render_report, run_campaign,
    BaselineCell, CampaignOptions, CampaignSpec,
};
use edgeflow::util::json::Json;

/// The acceptance sweep: {edgeflow_seq, edgeflow_latency, hierfl} x
/// {raw, top10}, sized for CI (2 rounds over 8 clients in 2 clusters).
fn sweep_spec_json() -> Json {
    Json::parse(
        r#"{
          "version": 1,
          "name": "sweep",
          "seed": 11,
          "base": {"engine": "native", "model": "fashion_mlp",
                   "optimizer": "momentum", "lr": 0.01,
                   "clients": 8, "clusters": 2, "local_steps": 1,
                   "rounds": 2, "batch_size": 4, "samples_per_client": 8,
                   "test_samples": 16, "eval_every": 1},
          "axes": [
            {"axis": "algorithm", "cells": [
              {"cell": "seq",  "delta": {"algorithm": "edgeflow_seq"}},
              {"cell": "lat",  "delta": {"algorithm": "edgeflow_latency"}},
              {"cell": "hier", "delta": {"algorithm": "hierfl"}}]},
            {"axis": "codec", "cells": [
              {"cell": "raw",   "delta": {"codec": "none"}},
              {"cell": "top10", "delta": {"codec": "top10"}}]}
          ]
        }"#,
    )
    .unwrap()
}

/// A 2x2 slice of the sweep for the cheaper structural tests.
fn small_spec() -> CampaignSpec {
    let mut v = sweep_spec_json();
    if let Json::Obj(m) = &mut v {
        m.insert("name".into(), "small".into());
        if let Some(Json::Arr(axes)) = m.get_mut("axes") {
            if let Some(cells) = axes[0].get("cells").and_then(Json::as_arr) {
                let trimmed = Json::obj(vec![
                    ("axis", "algorithm".into()),
                    ("cells", Json::arr(cells[..2].to_vec())),
                ]);
                axes[0] = trimmed;
            }
        }
    }
    CampaignSpec::from_json(&v).unwrap()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

fn tmp_str(name: &str) -> String {
    tmp(name).to_str().unwrap().to_string()
}

fn no_journal() -> CampaignOptions {
    CampaignOptions { artifacts: "artifacts_unused".into(), journal: None, max_cells: 0 }
}

#[test]
fn spec_file_loads_expands_and_validates() {
    let path = tmp_str("edgeflow_campaign_spec.json");
    std::fs::write(&path, sweep_spec_json().pretty()).unwrap();
    let spec = CampaignSpec::load(&path).unwrap();
    assert_eq!(spec.grid_size(), 6);
    let cells = spec.expand().unwrap();
    let ids: Vec<&str> = cells.iter().map(|c| c.id.as_str()).collect();
    assert_eq!(
        ids,
        ["seq+raw", "seq+top10", "lat+raw", "lat+top10", "hier+raw", "hier+top10"]
    );
    assert_eq!(cells[4].cfg.algorithm, Algorithm::HierFl);
    // cell names ride into run names; seeds are the derived ones
    assert!(cells.iter().all(|c| c.cfg.name == format!("sweep_{}", c.id)));
    assert!(cells.iter().all(|c| c.cfg.seed == c.seed));

    // a field typo in the file is a typed load error, not a silent no-op
    let bad = sweep_spec_json().pretty().replace("\"axes\"", "\"axis\"");
    std::fs::write(&path, bad).unwrap();
    let err = CampaignSpec::load(&path).unwrap_err();
    assert!(err.to_string().contains("axis"), "{err}");
}

#[test]
fn acceptance_sweep_runs_artifact_free_and_reports() {
    // The ISSUE's acceptance spec: three algorithms x two codecs on the
    // native engine, no artifacts anywhere, report + winners rendered.
    let spec = CampaignSpec::from_json(&sweep_spec_json()).unwrap();
    let cells = spec.expand().unwrap();
    let outcome = run_campaign(&spec, &cells, &no_journal()).unwrap();
    assert!(outcome.is_complete());
    assert_eq!(outcome.executed, 6);
    assert_eq!(outcome.skipped, 0);
    let results = outcome.complete_results().unwrap();
    assert!(results.iter().all(|r| r.final_loss.is_finite()));
    assert!(results.iter().all(|r| r.rounds == 2 && r.records.len() == 2));
    assert!(results.iter().all(|r| r.wire_bytes > 0 && r.clock_s > 0.0));
    // top10 compresses the wire against its raw sibling, same algorithm
    for pair in results.chunks(2) {
        assert!(
            pair[1].wire_bytes < pair[0].wire_bytes,
            "{}: top10 must shrink wire vs {}",
            pair[1].id,
            pair[0].id
        );
    }
    let report = render_report(&spec, &results);
    let j = Json::parse(&report).unwrap();
    assert_eq!(j.get("version").and_then(Json::as_u64), Some(1));
    assert_eq!(j.get("spec_digest").and_then(Json::as_str), Some(spec.digest().as_str()));
    assert_eq!(j.get("cells").and_then(Json::as_arr).unwrap().len(), 6);
    let winners = j.get("winners").unwrap();
    for table in ["max_final_accuracy", "min_final_loss", "min_wire_bytes", "min_clock_s"] {
        assert!(
            winners.get(table).and_then(|t| t.get("cell")).is_some(),
            "winner table {table} missing"
        );
    }
    // the wire winner is one of the top10 cells by construction
    let wire_winner = winners
        .get("min_wire_bytes")
        .and_then(|t| t.get("cell"))
        .and_then(Json::as_str)
        .unwrap();
    assert!(wire_winner.ends_with("+top10"), "{wire_winner}");
}

#[test]
fn reports_are_byte_identical_across_budget_splits() {
    // The nested-parallelism contract: however the core budget is split
    // between the cell pool and per-cell round pools, the rendered
    // report is the same bytes.
    let run_with = |workers: usize, cell_workers: usize| {
        let mut spec = small_spec();
        spec.workers = workers;
        spec.cell_workers = cell_workers;
        let cells = spec.expand().unwrap();
        let outcome = run_campaign(&spec, &cells, &no_journal()).unwrap();
        render_report(&spec, &outcome.complete_results().unwrap())
    };
    let reference = run_with(1, 1);
    for (w, cw) in [(4, 1), (4, 2), (2, 2), (0, 0)] {
        assert_eq!(
            run_with(w, cw),
            reference,
            "report bytes diverged at workers={w} cell_workers={cw}"
        );
    }
}

#[test]
fn interrupted_campaign_resumes_to_byte_identical_report() {
    let journal = tmp_str("edgeflow_campaign_resume.jsonl");
    let _ = std::fs::remove_file(&journal);
    let spec = small_spec();
    let cells = spec.expand().unwrap();

    // Uninterrupted reference run (no journal).
    let reference = {
        let outcome = run_campaign(&spec, &cells, &no_journal()).unwrap();
        render_report(&spec, &outcome.complete_results().unwrap())
    };

    // "Interrupt" after 2 of 4 cells: max_cells emulates the kill.
    let opts = CampaignOptions {
        artifacts: "artifacts_unused".into(),
        journal: Some(journal.clone()),
        max_cells: 2,
    };
    let partial = run_campaign(&spec, &cells, &opts).unwrap();
    assert!(!partial.is_complete());
    assert_eq!(partial.executed, 2);
    assert_eq!(partial.skipped, 0);
    let text = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(text.lines().count(), 3, "header + 2 cell records");

    // Re-run to completion: journaled cells are skipped, not re-trained.
    let opts = CampaignOptions { max_cells: 0, ..opts };
    let finished = run_campaign(&spec, &cells, &opts).unwrap();
    assert!(finished.is_complete());
    assert_eq!(finished.skipped, 2);
    assert_eq!(finished.executed, 2);
    let resumed = render_report(&spec, &finished.complete_results().unwrap());
    assert_eq!(resumed, reference, "resumed report must be byte-identical");

    // A third run touches nothing: everything comes from the journal.
    let again = run_campaign(&spec, &cells, &opts).unwrap();
    assert_eq!(again.skipped, 4);
    assert_eq!(again.executed, 0);

    // The journal is bound to the spec: a semantic change refuses it.
    let mut other = spec.clone();
    other.seed = 12345;
    let other_cells = other.expand().unwrap();
    let err = run_campaign(&other, &other_cells, &opts).unwrap_err();
    assert!(err.to_string().contains("digest"), "{err}");
}

#[test]
fn truncated_final_journal_record_is_dropped_not_fatal() {
    let journal = tmp_str("edgeflow_campaign_truncated.jsonl");
    let _ = std::fs::remove_file(&journal);
    let spec = small_spec();
    let cells = spec.expand().unwrap();
    let opts = CampaignOptions {
        artifacts: "artifacts_unused".into(),
        journal: Some(journal.clone()),
        max_cells: 2,
    };
    run_campaign(&spec, &cells, &opts).unwrap();
    // Cut the last record in half, as a kill mid-append would.
    let text = std::fs::read_to_string(&journal).unwrap();
    let cut = text.len() - 40;
    std::fs::write(&journal, &text[..cut]).unwrap();
    let outcome = run_campaign(&spec, &cells, &opts).unwrap();
    // One record survived, the torn one re-ran (max_cells=2 allows it),
    // so at least 3 of 4 cells are now journaled.
    assert_eq!(outcome.skipped, 1);
    assert_eq!(outcome.executed, 2);
}

#[test]
fn baseline_passes_itself_and_ordering_shifts_fails_regressions() {
    let spec = small_spec();
    let cells = spec.expand().unwrap();
    let outcome = run_campaign(&spec, &cells, &no_journal()).unwrap();
    let results = outcome.complete_results().unwrap();
    let report = render_report(&spec, &results);

    // A report is clean against itself at tolerance 0.
    let baseline = parse_baseline(&report).unwrap();
    let fresh: Vec<BaselineCell> =
        results.iter().map(BaselineCell::from_result).collect();
    assert!(regressions(&fresh, &baseline, 0.0).is_empty());

    // Pure ordering shifts are not regressions: cells match by id.
    let mut reversed = fresh.clone();
    reversed.reverse();
    assert!(regressions(&reversed, &baseline, 0.0).is_empty());

    // A seeded regression fails: one cell's loss nudged up...
    let mut worse = fresh.clone();
    worse[1].final_loss += 0.05;
    let regs = regressions(&worse, &baseline, 0.0);
    assert_eq!(regs.len(), 1, "{regs:?}");
    assert!(regs[0].contains("final_loss"), "{regs:?}");
    assert!(regs[0].contains(&fresh[1].id), "{regs:?}");
    // ...unless the tolerance absorbs it.
    assert!(regressions(&worse, &baseline, 0.5).is_empty());

    // Version drift is a parse error, never a misread.
    let drifted = report.replacen("\"version\": 1", "\"version\": 2", 1);
    assert!(parse_baseline(&drifted).is_err());
}

#[test]
fn bench_trajectory_accumulates_runs_atomically() {
    let path = tmp_str("edgeflow_campaign_bench.json");
    let _ = std::fs::remove_file(&path);
    let spec = small_spec();
    let cells = spec.expand().unwrap();
    let results = run_campaign(&spec, &cells, &no_journal())
        .unwrap()
        .complete_results()
        .unwrap();
    append_bench(&path, &spec, &results).unwrap();
    append_bench(&path, &spec, &results).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(j.get("version").and_then(Json::as_u64), Some(1));
    let runs = j.get("runs").and_then(Json::as_arr).unwrap();
    assert_eq!(runs.len(), 2, "two appends accumulate two runs");
    for run in runs {
        assert_eq!(
            run.get("spec_digest").and_then(Json::as_str),
            Some(spec.digest().as_str())
        );
        assert_eq!(run.get("cells").and_then(Json::as_usize), Some(4));
        assert_eq!(
            run.get("cells_summary").and_then(Json::as_arr).unwrap().len(),
            4
        );
        assert!(run.get("winners").is_some());
    }
    // identical inputs append identical run records (no timestamps)
    assert_eq!(runs[0].dump(), runs[1].dump());
}
