//! End-to-end coordinator tests on the **native** engine: real
//! multi-round training — data -> pure-Rust local updates -> Eq. 3
//! aggregation -> migration -> eval — with **zero artifacts**, so the
//! headline regression suites (loss decreases, unbalanced Eq. 3
//! weighting, workers=1≡N determinism, checkpoint/resume bit-identity,
//! full-state wire accounting) run in CI instead of skipping.  The
//! determinism suites sweep all three native optimizers
//! (sgd/momentum/adam) across both the MLP and the im2col CNN.

use std::sync::Arc;

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, EngineKind, ExperimentConfig,
    StragglerPolicy,
};
use edgeflow::fl::aggregate::reduce_states_weighted;
use edgeflow::fl::compress::Codec;
use edgeflow::fl::runner::{RunReport, Runner, RunnerCheckpoint};
use edgeflow::fl::session::AdaptiveDeadlineObserver;
use edgeflow::runtime::backend::{backend_for, TrainBackend};
use edgeflow::runtime::NativeBackend;
use edgeflow::util::json::Json;

fn backend() -> Arc<dyn TrainBackend> {
    Arc::new(NativeBackend::new())
}

/// Worker count for the round loop, settable by the CI matrix
/// (`EDGEFLOW_TEST_WORKERS=2 cargo test`).
fn env_workers() -> usize {
    std::env::var("EDGEFLOW_TEST_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// A CPU-cheap native federation: 12 clients in 4 clusters, one-hidden
/// -layer MLP, momentum SGD.
fn native_cfg(alg: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("native_{}", alg.name()),
        algorithm: alg,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 12,
        clusters: 4,
        local_steps: 2,
        rounds: 8,
        batch_size: 16,
        samples_per_client: 32,
        test_samples: 120,
        eval_every: 4,
        seed: 3,
        // Raw [0,1] pixels give the convex head a smoothness constant
        // around ||x||^2/2 ~ 100; heavy-ball stability needs
        // lr < 2(1+mu)/L ~ 0.038, and 0.01 converges in a handful of
        // steps anyway (initial gradients are large).
        lr: 0.01,
        optimizer: "momentum".into(),
        engine: EngineKind::Native,
        workers: env_workers(),
        ..ExperimentConfig::default()
    }
}

/// The deterministic half of two reports must agree bit-for-bit
/// (wall-clock phase timings excepted, by nature).
fn assert_reports_bit_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.name, b.name);
    assert_eq!(a.algorithm, b.algorithm);
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.total_byte_hops, b.total_byte_hops);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.metrics.rounds.len(), b.metrics.rounds.len());
    for (x, y) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.cluster, y.cluster, "round {}", x.round);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits());
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.comm_byte_hops, y.comm_byte_hops);
        assert_eq!(x.net_s.to_bits(), y.net_s.to_bits(), "round {}", x.round);
        assert_eq!(x.clock_s.to_bits(), y.clock_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stragglers, y.stragglers);
        assert_eq!(x.deferred, y.deferred);
    }
}

#[test]
fn native_training_reduces_loss_on_noniid_federation() {
    // The acceptance headline: a real multi-round training run with no
    // XLA artifacts anywhere, whose loss demonstrably decreases.
    for alg in [Algorithm::EdgeFlowSeq, Algorithm::FedAvg] {
        let mut cfg = native_cfg(alg);
        cfg.rounds = 16;
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        let report = r.run().unwrap();
        assert_eq!(report.rounds, 16);
        assert!(report.final_loss.is_finite());
        let losses: Vec<f64> =
            report.metrics.rounds.iter().map(|r| r.train_loss).collect();
        assert!(losses.iter().all(|l| l.is_finite()), "{}", alg.name());
        let q = losses.len() / 4;
        let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
        let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
        assert!(
            tail < head,
            "{}: loss must decrease ({head:.4} -> {tail:.4})",
            alg.name()
        );
        // Softmax over 10 classes starts near ln(10) ~ 2.30; training
        // must pull clearly below the random-init plateau.
        assert!(tail < 2.0, "{}: tail loss {tail:.4} never left init", alg.name());
        assert!(
            report.final_accuracy > 0.12,
            "{}: accuracy {} at chance",
            alg.name(),
            report.final_accuracy
        );
    }
}

#[test]
fn native_linear_variant_trains_end_to_end() {
    // The multinomial-logistic-regression architecture trains too (the
    // MLP is covered above); this guards the variant table.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.model = "fashion_linear".into();
    let report = Runner::with_backend(backend(), cfg).unwrap().run().unwrap();
    assert!(report.final_loss.is_finite());
    let first = report.metrics.rounds.first().unwrap().train_loss;
    let last = report.metrics.rounds.last().unwrap().train_loss;
    assert!(last < first, "linear variant must also learn: {first} -> {last}");
}

#[test]
fn native_eq3_weighting_follows_sample_counts_engine_free() {
    // The Eq. 3 regression suite, previously artifact-gated: clients
    // weigh into the aggregate by their actual |D_n|, not uniformly.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.clients = 2;
    cfg.clusters = 1;
    cfg.rounds = 1;
    let mut r = Runner::with_backend(backend(), cfg).unwrap();
    r.fed.clients[1].samples.truncate(16); // 32 vs 16 samples
    assert_eq!(r.client_weight(0), 32.0);
    assert_eq!(r.client_weight(1), 16.0);
    let (s0, _) = r.local_update_for(0, 0).unwrap();
    let (s1, _) = r.local_update_for(1, 0).unwrap();
    let (_, expected) =
        reduce_states_weighted(vec![(32.0, s0.clone()), (16.0, s1.clone())]).unwrap();
    let (_, uniform) =
        reduce_states_weighted(vec![(1.0, s0), (1.0, s1)]).unwrap();
    r.run().unwrap();
    assert_eq!(r.state().data, expected.data, "sample-count weighting");
    assert_ne!(r.state().data, uniform.data, "must not be uniform");
}

#[test]
fn native_worker_count_never_changes_results() {
    // The determinism contract on the native path: workers=N is
    // byte-identical to workers=1 (the acceptance criterion's 1 vs 4).
    let run_with = |workers: usize| {
        let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 6;
        cfg.dropout = 0.25;
        cfg.workers = workers;
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        let report = r.run().unwrap();
        (r.state().data.clone(), report)
    };
    let (state1, rep1) = run_with(1);
    for workers in [2usize, 4, 0] {
        let (state_n, rep_n) = run_with(workers);
        assert_eq!(state_n, state1, "state diverged at workers={workers}");
        assert_reports_bit_identical(&rep1, &rep_n);
    }
}

#[test]
fn native_runs_are_seed_deterministic() {
    let mk = || native_cfg(Algorithm::EdgeFlowRand);
    let mut r1 = Runner::with_backend(backend(), mk()).unwrap();
    let a = r1.run().unwrap();
    let mut r2 = Runner::with_backend(backend(), mk()).unwrap();
    let b = r2.run().unwrap();
    assert_eq!(r1.state().data, r2.state().data);
    assert_reports_bit_identical(&a, &b);
    let mut cfg = mk();
    cfg.seed = 99;
    let mut r3 = Runner::with_backend(backend(), cfg).unwrap();
    r3.run().unwrap();
    assert_ne!(r1.state().data, r3.state().data, "seed must matter");
}

#[test]
fn native_checkpoint_resume_is_bit_identical() {
    // Checkpoint/resume bit-identity on the native path, through the
    // serialized JSON and the `backend_for(&ck.cfg, ..)` resume route
    // the CLI uses — with defer + an impossible deadline so the
    // straggler pool rides the checkpoint too.
    let mk = || {
        let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 6;
        cfg.dropout = 0.2;
        cfg.deadline_s = 1e-9;
        cfg.straggler_policy = StragglerPolicy::Defer;
        cfg.eval_every = 2;
        cfg
    };
    let mut whole = Runner::with_backend(backend(), mk()).unwrap();
    let ref_report = whole.run().unwrap();

    let mut first = Runner::with_backend(backend(), mk()).unwrap();
    for _ in 0..3 {
        first.step().unwrap();
    }
    let ck = first.checkpoint().unwrap();
    let text = ck.to_json().pretty();
    let ck2 = RunnerCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(ck2.cursor, 3);
    assert_eq!(ck2.cfg.engine, EngineKind::Native, "engine rides the checkpoint");
    // The artifacts dir is irrelevant for a native checkpoint — this is
    // exactly what `edgeflow train --resume` does.
    let be = backend_for(&ck2.cfg, "artifacts_that_do_not_exist").unwrap();
    let mut resumed = Runner::resume(be, &ck2).unwrap();
    assert_eq!(resumed.round(), 3);
    let report = resumed.run().unwrap();
    assert_reports_bit_identical(&ref_report, &report);
    assert_eq!(whole.state().data, resumed.state().data, "final model state");
}

#[test]
fn native_codec_shrinks_wire_accounting_not_numbers() {
    // `codec` compresses the *accounting*: byte-hops and simulated
    // transfer times drop ~4x under int8, while every trained number is
    // bit-identical to the uncompressed run.
    let run_with = |codec: Codec| {
        let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 4;
        cfg.codec = codec;
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().data.clone(), rep)
    };
    let (state_raw, rep_raw) = run_with(Codec::None);
    let (state_q, rep_q) = run_with(Codec::QuantizeInt8);
    assert_eq!(state_raw, state_q, "codec must not touch the model");
    for (a, b) in rep_raw.metrics.rounds.iter().zip(&rep_q.metrics.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert!(b.comm_byte_hops < a.comm_byte_hops, "round {}", a.round);
        assert!(b.net_s <= a.net_s, "smaller transfers cannot be slower");
    }
    let ratio = rep_q.total_byte_hops as f64 / rep_raw.total_byte_hops as f64;
    assert!(
        (0.2..0.3).contains(&ratio),
        "int8 wire ratio {ratio} should be ~0.25"
    );
}

#[test]
fn native_adaptive_deadline_cuts_slow_uploads_after_warmup() {
    // The adaptive-deadline observer at a deliberately starving slack:
    // warmup rounds run free, then every upload misses slack x EWMA.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 6;
    let mut r = Runner::with_backend(backend(), cfg).unwrap();
    r.add_observer(Box::new(AdaptiveDeadlineObserver::with_params(1e-6, 0.5, 2)));
    let report = r.run().unwrap();
    let recs = &report.metrics.rounds;
    assert!(recs[0].stragglers.is_empty(), "warmup round 0");
    assert!(recs[1].stragglers.is_empty(), "warmup round 1");
    for rec in &recs[2..] {
        assert_eq!(
            rec.stragglers.len(),
            3,
            "round {}: whole cluster late under the starving deadline",
            rec.round
        );
        assert!(rec.train_loss.is_nan(), "drop policy loses the round");
    }

    // A generous slack must not perturb the run at all.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 6;
    let mut free = Runner::with_backend(backend(), cfg).unwrap();
    free.add_observer(Box::new(AdaptiveDeadlineObserver::with_params(1e9, 0.3, 2)));
    let rep_free = free.run().unwrap();
    assert!(rep_free.metrics.rounds.iter().all(|r| r.stragglers.is_empty()));
    assert!(rep_free.final_loss.is_finite());
}

#[test]
fn native_defer_policy_folds_late_updates() {
    // Straggler re-inclusion end-to-end on the native path: round 0 is
    // lost but held, round 1 folds the pending updates.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.deadline_s = 1e-9;
    cfg.straggler_policy = StragglerPolicy::Defer;
    let mut r = Runner::with_backend(backend(), cfg).unwrap();
    let members = r.fed.cluster_members(0);
    let out0 = r.step().unwrap();
    assert!(out0.is_lost());
    assert_eq!(r.pending_deferrals(), members);
    let out1 = r.step().unwrap();
    assert!(!out1.is_lost());
    assert_eq!(out1.record().deferred, members);
}

#[test]
fn native_rejects_unknown_configs() {
    // Unsupported names fail fast with a config error rather than
    // producing silently-wrong numbers: the six-conv XLA artifact
    // variant has no native port, and rmsprop is nobody's optimizer.
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.model = "fashion_cnn_slim".into();
    assert!(Runner::with_backend(backend(), cfg).is_err());
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.optimizer = "rmsprop".into();
    assert!(Runner::with_backend(backend(), cfg).is_err());
}

/// The (model, optimizer, lr) grid the determinism suites sweep: every
/// native optimizer on both architectures.  Momentum-family rates stay
/// at the smoothness-safe 0.01 (see [`native_cfg`]); adam gets the
/// paper's 1e-3.
const GRID: [(&str, &str, f64); 6] = [
    ("fashion_mlp", "sgd", 0.01),
    ("fashion_mlp", "momentum", 0.01),
    ("fashion_mlp", "adam", 1e-3),
    ("fashion_cnn_slim_fast", "sgd", 0.01),
    ("fashion_cnn_slim_fast", "momentum", 0.01),
    ("fashion_cnn_slim_fast", "adam", 1e-3),
];

/// A CPU-cheap grid cell: 3 rounds over one 3-client cluster per round,
/// sized so the CNN cells stay fast in debug builds.
fn grid_cfg(model: &str, opt: &str, lr: f64) -> ExperimentConfig {
    let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
    cfg.name = format!("grid_{model}_{opt}");
    cfg.model = model.into();
    cfg.optimizer = opt.into();
    cfg.lr = lr;
    cfg.rounds = 3;
    cfg.local_steps = 1;
    cfg.batch_size = 8;
    cfg.samples_per_client = 16;
    cfg.test_samples = 60;
    cfg.eval_every = 3;
    cfg
}

#[test]
fn native_bit_identity_at_any_worker_count_all_optimizers_and_archs() {
    // The acceptance criterion: workers 1≡2≡4≡0 for sgd, momentum, and
    // adam on both the MLP and the CNN.  Batched kernels with fixed
    // accumulation order plus the fixed-order reduction make every
    // report a pure function of the config.
    for (model, opt, lr) in GRID {
        let run_with = |workers: usize| {
            let mut cfg = grid_cfg(model, opt, lr);
            cfg.workers = workers;
            let mut r = Runner::with_backend(backend(), cfg).unwrap();
            let report = r.run().unwrap();
            (r.state().data.clone(), report)
        };
        let (state1, rep1) = run_with(1);
        for workers in [2usize, 4, 0] {
            let (state_n, rep_n) = run_with(workers);
            assert_eq!(
                state_n, state1,
                "{model}/{opt}: state diverged at workers={workers}"
            );
            assert_reports_bit_identical(&rep1, &rep_n);
        }
    }
}

#[test]
fn native_checkpoint_resume_bit_identical_all_optimizers_and_archs() {
    // Checkpoint at round 1, resume, finish: bit-identical to the
    // uninterrupted run for every optimizer × architecture — i.e. the
    // momentum velocity and both Adam moment runs (plus the adam_t step
    // counter) genuinely ride the serialized state blob.
    for (model, opt, lr) in GRID {
        let mut whole =
            Runner::with_backend(backend(), grid_cfg(model, opt, lr)).unwrap();
        let ref_report = whole.run().unwrap();

        let mut first =
            Runner::with_backend(backend(), grid_cfg(model, opt, lr)).unwrap();
        first.step().unwrap();
        let ck = first.checkpoint().unwrap();
        let text = ck.to_json().pretty();
        let ck2 = RunnerCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        let be = backend_for(&ck2.cfg, "artifacts_that_do_not_exist").unwrap();
        let mut resumed = Runner::resume(be, &ck2).unwrap();
        assert_eq!(resumed.round(), 1, "{model}/{opt}");
        let report = resumed.run().unwrap();
        assert_reports_bit_identical(&ref_report, &report);
        assert_eq!(
            whole.state().data,
            resumed.state().data,
            "{model}/{opt}: final model state"
        );
    }
}

#[test]
fn native_cnn_preset_trains_artifact_free_with_decreasing_loss() {
    // The previously XLA-artifact-gated `e2e_cnn` preset now runs on
    // the native engine: conv -> ReLU -> pool -> dense over the im2col
    // kernels, trained with native Adam — scaled down to test size but
    // with the preset's dataset/distribution/model intact.
    let mut cfg = edgeflow::config::preset("e2e_cnn").unwrap();
    assert_eq!(cfg.model, "fashion_cnn_slim_fast");
    cfg.engine = EngineKind::Native;
    cfg.optimizer = "adam".into();
    cfg.clients = 12;
    cfg.clusters = 4;
    cfg.rounds = 8;
    cfg.local_steps = 2;
    cfg.batch_size = 16;
    cfg.samples_per_client = 32;
    cfg.test_samples = 120;
    cfg.eval_every = 4;
    cfg.workers = env_workers();
    let mut r = Runner::with_backend(backend(), cfg).unwrap();
    let report = r.run().unwrap();
    assert_eq!(report.rounds, 8);
    let losses: Vec<f64> =
        report.metrics.rounds.iter().map(|r| r.train_loss).collect();
    assert!(losses.iter().all(|l| l.is_finite()));
    // Each half covers every cluster once (4 clusters, 8 rounds), so the
    // halves are comparable under the non-IID split.
    let head: f64 = losses[..4].iter().sum::<f64>() / 4.0;
    let tail: f64 = losses[4..].iter().sum::<f64>() / 4.0;
    assert!(tail < head, "CNN must learn: {head:.4} -> {tail:.4}");
    assert!((0.0..=1.0).contains(&report.final_accuracy));
}

#[test]
fn native_wire_accounting_charges_full_state_per_optimizer() {
    // Regression for the params-only wire bug: the migrating payload is
    // the whole state, so byte-hops must scale with `layout.total` —
    // momentum (params + velocity) costs exactly 2x sgd's wire, adam
    // (params + two moment runs + step counter) (3n+1)/n x.  Routing
    // and round plans are optimizer-independent, so the ratios are
    // exact.
    let run_with = |opt: &str, lr: f64| {
        let mut cfg = native_cfg(Algorithm::EdgeFlowSeq);
        cfg.name = format!("wire_{opt}");
        cfg.optimizer = opt.into();
        cfg.lr = lr;
        cfg.rounds = 2;
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().layout.total as u64, rep.total_byte_hops)
    };
    let (total_sgd, hops_sgd) = run_with("sgd", 0.01);
    let (total_mom, hops_mom) = run_with("momentum", 0.01);
    let (total_adam, hops_adam) = run_with("adam", 1e-3);
    assert!(hops_sgd > 0);
    assert_eq!(total_mom, 2 * total_sgd, "velocity mirrors the params");
    assert_eq!(total_adam, 3 * total_sgd + 1, "two moment runs + adam_t");
    assert_eq!(
        hops_mom, 2 * hops_sgd,
        "momentum's velocity must be paid for on the wire"
    );
    // Cross-multiplied exact ratio: hops scale linearly in state size.
    assert_eq!(
        hops_adam * total_sgd,
        hops_sgd * total_adam,
        "adam's moments must be paid for on the wire"
    );
}
