//! End-to-end observability tests: real traced training runs on the
//! native engine, checked against the ISSUE's acceptance criteria —
//! schema-valid dual-clock JSONL, a logical event stream that is
//! bit-identical at any worker count, a Perfetto-loadable Chrome
//! export with monotone per-lane timestamps, and the zero-cost
//! contract (tracing off leaves every report byte-identical).

use std::collections::BTreeMap;
use std::sync::Arc;

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, EngineKind, ExperimentConfig,
};
use edgeflow::fl::runner::Runner;
use edgeflow::obs::{validate_event, TRACE_SCHEMA_VERSION};
use edgeflow::runtime::backend::TrainBackend;
use edgeflow::runtime::NativeBackend;
use edgeflow::util::json::Json;

fn backend() -> Arc<dyn TrainBackend> {
    Arc::new(NativeBackend::new())
}

/// Unique temp path per test so parallel `cargo test` threads never
/// collide.
fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("edgeflow_obs_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// A CPU-cheap traced federation: 12 clients in 4 clusters on the MLP,
/// with dropout so straggler/net events are exercised.
fn traced_cfg(tag: &str, trace: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("obs_{tag}"),
        algorithm: Algorithm::EdgeFlowSeq,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 12,
        clusters: 4,
        local_steps: 1,
        rounds: 4,
        batch_size: 8,
        samples_per_client: 16,
        test_samples: 60,
        eval_every: 2,
        seed: 7,
        lr: 0.01,
        optimizer: "momentum".into(),
        engine: EngineKind::Native,
        dropout: 0.25,
        trace: trace.to_string(),
        trace_level: "full".into(),
        ..ExperimentConfig::default()
    }
}

/// Read a trace back as parsed JSON lines (skipping blanks).
fn read_trace(path: &str) -> Vec<Json> {
    std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

/// Project a trace down to its **logical** content: wall-clock fields
/// (timing, by nature nondeterministic) stripped, `workerN` lanes
/// collapsed (which thread ran a client is scheduling, not logic), and
/// the pool span's resolved worker count dropped.  Sorted, so equality
/// is multiset equality.
fn logical_lines(path: &str) -> Vec<String> {
    let mut out: Vec<String> = read_trace(path)
        .into_iter()
        .map(|j| {
            let Json::Obj(mut m) = j else { panic!("non-object trace line") };
            m.remove("wall_ns");
            m.remove("wall_dur_ns");
            if let Some(Json::Str(lane)) = m.get_mut("lane") {
                if lane.starts_with("worker") {
                    *lane = "worker".into();
                }
            }
            if let Some(Json::Obj(attrs)) = m.get_mut("attrs") {
                attrs.remove("workers");
            }
            Json::Obj(m).dump()
        })
        .collect();
    out.sort();
    out
}

#[test]
fn traced_run_emits_schema_valid_dual_clock_jsonl() {
    let path = tmp("schema");
    let cfg = traced_cfg("schema", &path);
    let mut r = Runner::with_backend(backend(), cfg).unwrap();
    r.run().unwrap();
    let lines = read_trace(&path);
    assert!(lines.len() > 10, "traced run produced only {} events", lines.len());
    for j in &lines {
        validate_event(j).unwrap();
    }
    // First line is the schema-versioned header.
    let h = &lines[0];
    assert_eq!(h.str_field("ev").unwrap(), "header");
    assert_eq!(h.str_field("format").unwrap(), "edgeflow-trace");
    assert_eq!(h.req("v").unwrap().as_u64(), Some(TRACE_SCHEMA_VERSION));
    assert_eq!(h.str_field("run").unwrap(), "obs_schema");
    // Both clocks appear: wall-only client spans on worker lanes, and
    // sim-clocked network spans on route lanes.
    let spans: Vec<&Json> = lines
        .iter()
        .filter(|j| j.get("ev").and_then(Json::as_str) == Some("span"))
        .collect();
    assert!(spans
        .iter()
        .any(|j| j.str_field("cat").unwrap() == "client"
            && j.str_field("lane").unwrap().starts_with("worker")
            && j.req("wall_dur_ns").unwrap().as_u64().is_some()));
    assert!(spans
        .iter()
        .any(|j| j.str_field("cat").unwrap() == "net"
            && j.get("sim_dur_s").and_then(Json::as_f64).is_some()
            && j.get("attrs").and_then(|a| a.get("bytes")).is_some()));
    // Round spans carry the sim-clock round window; phase spans carry
    // the wall-clock laps; the file ends with a metrics snapshot.
    assert!(spans.iter().any(|j| j.str_field("cat").unwrap() == "round"));
    assert!(spans.iter().any(|j| j.str_field("cat").unwrap() == "phase"));
    let metrics: Vec<&Json> = lines
        .iter()
        .filter(|j| j.get("ev").and_then(Json::as_str) == Some("metrics"))
        .collect();
    assert_eq!(metrics.len(), 1, "exactly one final metrics snapshot");
    let counters = metrics[0].req("registry").unwrap().req("counters").unwrap();
    assert_eq!(counters.get("rounds_total").and_then(Json::as_u64), Some(4));
    assert!(counters.get("transfers_total").and_then(Json::as_u64).unwrap() > 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn logical_event_stream_is_identical_at_any_worker_count() {
    // The determinism tentpole: what happened (spans, attrs, sim times,
    // metrics) is a pure function of the config — workers only change
    // wall-clock numbers and which thread lane a client ran on.
    let run_with = |workers: usize| {
        let path = tmp(&format!("ident_w{workers}"));
        let mut cfg = traced_cfg("ident", &path);
        cfg.workers = workers;
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        r.run().unwrap();
        let lines = logical_lines(&path);
        let _ = std::fs::remove_file(&path);
        lines
    };
    let seq = run_with(1);
    assert!(!seq.is_empty());
    for workers in [2usize, 4] {
        let par = run_with(workers);
        assert_eq!(
            seq, par,
            "logical event stream diverged at workers={workers}"
        );
    }
}

#[test]
fn chrome_export_is_valid_json_with_monotone_lanes() {
    let path = tmp("chrome_in");
    let out = tmp("chrome_out");
    let cfg = traced_cfg("chrome", &path);
    Runner::with_backend(backend(), cfg).unwrap().run().unwrap();
    let n = edgeflow::obs::chrome::export_chrome(&path, &out).unwrap();
    assert!(n > 0);
    let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    let events = j.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut pids = std::collections::BTreeSet::new();
    let mut last: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for e in events {
        let ph = e.str_field("ph").unwrap();
        assert!(
            ["X", "i", "M"].contains(&ph),
            "unexpected Chrome phase {ph:?}"
        );
        if ph == "M" {
            continue; // metadata events carry no timestamp ordering
        }
        let pid = e.req("pid").unwrap().as_u64().unwrap();
        let tid = e.req("tid").unwrap().as_u64().unwrap();
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        assert!(ts >= 0.0);
        pids.insert(pid);
        if let Some(prev) = last.insert((pid, tid), ts) {
            assert!(
                ts >= prev,
                "pid {pid} tid {tid}: ts went backwards ({prev} -> {ts})"
            );
        }
    }
    // Both clock domains render: wall lanes (pid 1) and sim lanes (pid 2).
    assert_eq!(
        pids.into_iter().collect::<Vec<_>>(),
        vec![1, 2],
        "expected wall + sim process groups"
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn summarize_rolls_up_a_real_run() {
    let path = tmp("summary");
    let cfg = traced_cfg("summary", &path);
    Runner::with_backend(backend(), cfg).unwrap().run().unwrap();
    let s = edgeflow::obs::summary::summarize(&path).unwrap();
    assert!(s.events > 0);
    assert!(s.header.is_some());
    assert!(s.metrics.is_some());
    let rounds = s
        .by_kind
        .get(&("round".to_string(), "round".to_string()))
        .expect("round rollup");
    assert_eq!(rounds.count, 4);
    let clients = s
        .by_kind
        .get(&("client".to_string(), "local_update".to_string()))
        .expect("client rollup");
    assert!(clients.count > 0);
    assert!(!s.by_lane.is_empty(), "net spans roll up per route lane");
    assert!(s.by_lane.values().all(|r| r.bytes > 0));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tracing_off_is_byte_identical_to_traced_run() {
    // The zero-cost contract both ways: tracing must never perturb the
    // training numbers, and disabling it must not change a single byte
    // of the metrics surface.
    let path = tmp("offon");
    let run_with = |trace: &str| {
        let cfg = traced_cfg("offon", trace);
        let mut r = Runner::with_backend(backend(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().data.clone(), rep)
    };
    let (state_off, rep_off) = run_with("");
    let (state_on, rep_on) = run_with(&path);
    assert_eq!(state_off, state_on, "tracing must not touch the model");
    assert_eq!(
        rep_off.final_accuracy.to_bits(),
        rep_on.final_accuracy.to_bits()
    );
    assert_eq!(rep_off.final_loss.to_bits(), rep_on.final_loss.to_bits());
    assert_eq!(rep_off.total_byte_hops, rep_on.total_byte_hops);
    assert_eq!(
        rep_off.metrics.to_csv().as_bytes(),
        rep_on.metrics.to_csv().as_bytes(),
        "metrics CSV must be byte-identical with tracing on or off"
    );
    assert_eq!(
        rep_off.metrics.to_json().pretty(),
        rep_on.metrics.to_json().pretty(),
        "metrics JSON must be byte-identical with tracing on or off"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn restore_accepts_checkpoints_across_trace_settings() {
    // Trace path and level are observability knobs, not experiment
    // identity: a checkpoint from an untraced run restores into a traced
    // runner (and vice versa) and replays bit-identically.
    let mut whole = Runner::with_backend(backend(), traced_cfg("ck", "")).unwrap();
    let ref_report = whole.run().unwrap();

    let mut first = Runner::with_backend(backend(), traced_cfg("ck", "")).unwrap();
    for _ in 0..2 {
        first.step().unwrap();
    }
    let ck = first.checkpoint().unwrap();

    let path = tmp("ck_resume");
    let mut resumed =
        Runner::with_backend(backend(), traced_cfg("ck", &path)).unwrap();
    resumed.restore(&ck).unwrap();
    assert_eq!(resumed.round(), 2);
    let report = resumed.run().unwrap();
    assert_eq!(
        ref_report.final_loss.to_bits(),
        report.final_loss.to_bits(),
        "resume across trace settings must stay bit-identical"
    );
    assert_eq!(ref_report.total_byte_hops, report.total_byte_hops);
    assert_eq!(whole.state().data, resumed.state().data);
    let _ = std::fs::remove_file(&path);
}
