//! End-to-end coordinator tests over the real artifacts: the full
//! Runner loop (data -> PJRT local updates -> aggregation -> migration ->
//! eval) for every algorithm.

use std::sync::{Arc, Mutex};

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, ExperimentConfig, StragglerPolicy,
    TopologyKind,
};
use edgeflow::fl::aggregate::reduce_states_weighted;
use edgeflow::fl::comm::RoundComm;
use edgeflow::fl::runner::{RunReport, Runner, RunnerCheckpoint};
use edgeflow::fl::session::{
    MetricsCsvObserver, RoundControl, RoundObserver, RoundOutcome,
};
use edgeflow::fl::strategy::RoundPlan;
use edgeflow::runtime::executor::Engine;
use edgeflow::runtime::params::ModelState;
use edgeflow::util::json::Json;

fn engine() -> Option<Arc<Engine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Engine::load("artifacts").expect("engine")))
}

/// Worker count for the round loop, settable by the CI matrix
/// (`EDGEFLOW_TEST_WORKERS=2 cargo test`).  Reports are bit-identical at
/// any value, so the whole suite must pass unchanged.
fn env_workers() -> usize {
    std::env::var("EDGEFLOW_TEST_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn tiny_cfg(alg: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("test_{}", alg.name()),
        algorithm: alg,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 20,
        clusters: 4,
        local_steps: 5,
        rounds: 8,
        samples_per_client: 80,
        test_samples: 200,
        eval_every: 4,
        seed: 3,
        lr: 2e-3, // short runs: push Adam a little harder than the paper default
        workers: env_workers(),
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_algorithm_trains_and_improves() {
    let Some(e) = engine() else { return };
    // Random init on 10 classes ~= 10% accuracy; a short run must beat it
    // clearly for the averaging algorithms.
    for alg in [
        Algorithm::FedAvg,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
        Algorithm::HierFl,
    ] {
        let mut cfg = tiny_cfg(alg);
        cfg.rounds = 40;
        if alg == Algorithm::HierFl {
            cfg.rounds = 6; // trains all clients per round; keep it short
        }
        let report = Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap();
        assert!(
            report.final_accuracy > 0.2,
            "{}: accuracy {} too low",
            alg.name(),
            report.final_accuracy
        );
        assert!(report.final_loss.is_finite());
        assert_eq!(report.metrics.rounds.len(), report.rounds);
        // training must actually reduce the loss; compare quarter-means
        // since per-round loss is noisy under client resampling
        let losses: Vec<f64> =
            report.metrics.rounds.iter().map(|r| r.train_loss).collect();
        let q = (losses.len() / 4).max(1);
        let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
        let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
        assert!(tail < head, "{}: loss {head:.4} -> {tail:.4}", alg.name());
    }
}

#[test]
fn seqfl_runs_without_aggregation() {
    // Under IID data the sequential chain learns; under heavy non-IID it
    // exhibits the catastrophic-forgetting pathology the paper cites as
    // motivation — both behaviours are exercised here.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::SeqFl);
    cfg.distribution = Distribution::Iid;
    cfg.rounds = 20;
    cfg.lr = 1e-3;
    let report = Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy > 0.2, "iid seqfl: {}", report.final_accuracy);

    // Non-IID: the model chases each client's 1-2 classes; accuracy stays
    // far below the averaging algorithms at the same budget.
    let mut cfg = tiny_cfg(Algorithm::SeqFl);
    cfg.distribution = Distribution::NonIid { major_fraction: 1.0 };
    cfg.rounds = 20;
    cfg.lr = 1e-3;
    let forgetful = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert!(forgetful.final_loss.is_finite());
    assert!(
        forgetful.final_accuracy < report.final_accuracy,
        "non-IID seqfl should trail IID seqfl ({} vs {})",
        forgetful.final_accuracy,
        report.final_accuracy
    );
}

#[test]
fn single_cluster_edgeflow_equals_fedavg_full_participation() {
    // With M = 1, EdgeFLow's active cluster is all clients and FedAvg's
    // sample (N_m = N) is also all clients: identical participant sets,
    // identical batches, identical uniform aggregation => identical model.
    let Some(e) = engine() else { return };
    let mut a = tiny_cfg(Algorithm::EdgeFlowSeq);
    a.clusters = 1;
    a.rounds = 3;
    let mut b = tiny_cfg(Algorithm::FedAvg);
    b.clusters = 1;
    b.rounds = 3;
    let mut ra = Runner::with_engine(e.clone(), a).unwrap();
    let rep_a = ra.run().unwrap();
    let mut rb = Runner::with_engine(e, b).unwrap();
    let rep_b = rb.run().unwrap();
    assert_eq!(ra.state().data, rb.state().data, "models must be identical");
    assert_eq!(rep_a.final_accuracy, rep_b.final_accuracy);
}

#[test]
fn runs_are_seed_deterministic() {
    let Some(e) = engine() else { return };
    let mk = || tiny_cfg(Algorithm::EdgeFlowRand);
    let mut r1 = Runner::with_engine(e.clone(), mk()).unwrap();
    let a = r1.run().unwrap();
    let mut r2 = Runner::with_engine(e.clone(), mk()).unwrap();
    let b = r2.run().unwrap();
    assert_eq!(r1.state().data, r2.state().data);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_byte_hops, b.total_byte_hops);
    // Different seed must actually change the run.
    let mut cfg = mk();
    cfg.seed = 99;
    let mut r3 = Runner::with_engine(e, cfg).unwrap();
    r3.run().unwrap();
    assert_ne!(r1.state().data, r3.state().data);
}

#[test]
fn edgeflow_communicates_less_than_fedavg_on_deep_topology() {
    let Some(e) = engine() else { return };
    let run = |alg: Algorithm| {
        let mut cfg = tiny_cfg(alg);
        cfg.topology = TopologyKind::DepthLinear;
        cfg.rounds = 6;
        Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap()
    };
    let fedavg = run(Algorithm::FedAvg);
    let edge = run(Algorithm::EdgeFlowSeq);
    assert!(
        (edge.total_byte_hops as f64) < 0.5 * fedavg.total_byte_hops as f64,
        "edgeflow {} vs fedavg {}",
        edge.total_byte_hops,
        fedavg.total_byte_hops
    );
}

#[test]
fn cnn_variant_runs_one_round() {
    let Some(e) = engine() else { return };
    let cfg = ExperimentConfig {
        name: "cnn_smoke".into(),
        algorithm: Algorithm::EdgeFlowSeq,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::Iid,
        model: "fashion_cnn_slim_fast".into(),
        clients: 4,
        clusters: 2,
        local_steps: 5,
        rounds: 1,
        samples_per_client: 64,
        test_samples: 100,
        eval_every: 1,
        seed: 0,
        ..ExperimentConfig::default()
    };
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy >= 0.0);
}

#[test]
fn config_artifact_cross_validation() {
    let Some(e) = engine() else { return };
    // K without an artifact
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.local_steps = 3;
    assert!(Runner::with_engine(e.clone(), cfg).is_err());
    // wrong dataset for the model
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.dataset = DatasetKind::SynthCifar; // model stays fashion_mlp
    assert!(Runner::with_engine(e.clone(), cfg).is_err());
    // batch size mismatch
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.batch_size = 32;
    cfg.samples_per_client = 64;
    assert!(Runner::with_engine(e, cfg).is_err());
}

#[test]
fn edgeflow_hop_minimizes_migration_cost() {
    // On the depth-linear chain, the hop-aware circuit's migrations should
    // cost no more than the sequential circuit's (both visit every cluster
    // each cycle; hop-aware orders by BS proximity).
    let Some(e) = engine() else { return };
    let run = |alg: Algorithm| {
        let mut cfg = tiny_cfg(alg);
        cfg.topology = TopologyKind::DepthLinear;
        cfg.rounds = 12;
        Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap()
    };
    let hop = run(Algorithm::EdgeFlowHop);
    let seq = run(Algorithm::EdgeFlowSeq);
    assert!(hop.final_accuracy > 0.1);
    assert!(
        hop.total_byte_hops <= seq.total_byte_hops,
        "hop-aware {} vs sequential {}",
        hop.total_byte_hops,
        seq.total_byte_hops
    );
}

#[test]
fn dropout_one_keeps_model_frozen() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 4;
    cfg.dropout = 1.0;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_eq!(r.state().data, before, "all-dropped rounds must not move the model");
    assert_eq!(report.total_byte_hops, 0);
    assert_eq!(report.metrics.rounds.len(), 4);
    // Every lost round must still be recorded: NaN losses, zero traffic,
    // zero simulated network time — and the run must not error out.
    for rec in &report.metrics.rounds {
        assert!(rec.train_loss.is_nan(), "round {} has a loss", rec.round);
        assert!(rec.test_loss.is_nan());
        assert_eq!(rec.comm_byte_hops, 0);
        assert_eq!(rec.net_s, 0.0);
    }
}

#[test]
fn weighted_aggregation_follows_sample_counts() {
    // The Eq. 3 bugfix: clients weigh into the cluster aggregate by their
    // actual |D_n|, not uniformly.  Unbalance a 2-client cluster, compose
    // the expected aggregate from per-client probes, and check the round
    // loop reproduces it bit-for-bit (and diverges from uniform weights).
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.clients = 2;
    cfg.clusters = 1;
    cfg.rounds = 1;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    r.fed.clients[1].samples.truncate(16); // 80 vs 16 samples
    assert_eq!(r.client_weight(0), 80.0);
    assert_eq!(r.client_weight(1), 16.0);
    let (s0, _) = r.local_update_for(0, 0).unwrap();
    let (s1, _) = r.local_update_for(1, 0).unwrap();
    let (_, expected) = edgeflow::fl::aggregate::reduce_states_weighted(vec![
        (80.0, s0.clone()),
        (16.0, s1.clone()),
    ])
    .unwrap();
    let (_, uniform) =
        edgeflow::fl::aggregate::reduce_states_weighted(vec![(1.0, s0), (1.0, s1)])
            .unwrap();
    r.run().unwrap();
    assert_eq!(r.state().data, expected.data, "sample-count weighting");
    assert_ne!(r.state().data, uniform.data, "must not be uniform");
}

#[test]
fn worker_count_never_changes_results() {
    // The determinism contract of the parallel round loop: workers=N is
    // byte-identical to workers=1 — model state, per-round losses,
    // accuracies and byte-hops.  Dropout is on so the failure-injection
    // stream is exercised too (it is drawn on the main thread, before the
    // fan-out, and must not depend on worker scheduling).
    let Some(e) = engine() else { return };
    let run_with = |workers: usize| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 6;
        cfg.dropout = 0.25;
        cfg.workers = workers;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let report = r.run().unwrap();
        (r.state().data.clone(), report)
    };
    let (state1, rep1) = run_with(1);
    for workers in [2usize, 4, 0] {
        let (state_n, rep_n) = run_with(workers);
        assert_eq!(state_n, state1, "state diverged at workers={workers}");
        assert_eq!(rep_n.total_byte_hops, rep1.total_byte_hops);
        assert_eq!(
            rep_n.final_accuracy.to_bits(),
            rep1.final_accuracy.to_bits(),
            "accuracy diverged at workers={workers}"
        );
        for (a, b) in rep_n.metrics.rounds.iter().zip(&rep1.metrics.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.comm_byte_hops, b.comm_byte_hops);
        }
    }
}

#[test]
fn rounds_report_simulated_network_time() {
    // net_s used to be hardcoded 0.0; every round that moves bytes must
    // now carry a positive simulated transfer makespan.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 4;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    for rec in &report.metrics.rounds {
        assert!(rec.comm_byte_hops > 0);
        assert!(rec.net_s > 0.0, "round {} has no net time", rec.round);
    }
    assert!(report.metrics.total_net_s() > 0.0);
}

#[test]
fn clock_s_accumulates_round_makespans() {
    // The persistent DES: each round opens at the previous round's clock,
    // so clock_s is the running sum of per-round makespans — the simulated
    // wall-clock axis for time-resolved convergence curves.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 5;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let report = r.run().unwrap();
    let mut expected = 0.0;
    for rec in &report.metrics.rounds {
        expected += rec.net_s;
        assert!(
            (rec.clock_s - expected).abs() < 1e-9,
            "round {}: clock {} vs accumulated {}",
            rec.round,
            rec.clock_s,
            expected
        );
    }
    assert!((r.net_clock_s() - expected).abs() < 1e-9);
}

#[test]
fn net_s_monotone_in_model_size() {
    // Same federation, same schedule, same transfers — only the model's
    // wire bytes differ, so per-round simulated network time must not
    // decrease with model size.
    let Some(e) = engine() else { return };
    let run = |model: &str| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.model = model.into();
        cfg.clients = 4;
        cfg.clusters = 2;
        cfg.rounds = 3;
        cfg.samples_per_client = 64;
        cfg.test_samples = 100;
        cfg.eval_every = 3;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let bytes = r.state().param_bytes();
        (bytes, r.run().unwrap())
    };
    let (bytes_a, rep_a) = run("fashion_mlp");
    let (bytes_b, rep_b) = run("fashion_cnn_slim_fast");
    let ((_, small), (b_big, big)) = if bytes_a <= bytes_b {
        ((bytes_a, rep_a), (bytes_b, rep_b))
    } else {
        ((bytes_b, rep_b), (bytes_a, rep_a))
    };
    for (s, b) in small.metrics.rounds.iter().zip(&big.metrics.rounds) {
        assert!(
            b.net_s >= s.net_s,
            "round {}: {} bytes took {}s vs {}s",
            s.round,
            b_big,
            b.net_s,
            s.net_s
        );
    }
}

#[test]
fn all_dropped_rounds_leave_net_clock_unchanged() {
    // A lost round moves no bytes, so the persistent sim clock must not
    // advance — the simulated time axis only runs when traffic flows.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.dropout = 1.0;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let report = r.run().unwrap();
    assert_eq!(r.net_clock_s(), 0.0);
    for rec in &report.metrics.rounds {
        assert_eq!(rec.net_s, 0.0);
        assert_eq!(rec.clock_s, 0.0);
        assert!(rec.stragglers.is_empty());
    }
}

#[test]
fn impossible_deadline_freezes_model_but_charges_traffic() {
    // deadline_s far below any physical delivery time: every upload is
    // late, every round records its cluster as stragglers, the model
    // never moves — but the (late) traffic is still charged and the sim
    // clock still advances.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.deadline_s = 1e-9;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_eq!(r.state().data, before, "all-straggled rounds must not train");
    assert!(report.total_byte_hops > 0, "late uploads still transmit");
    assert!(r.net_clock_s() > 0.0);
    for rec in &report.metrics.rounds {
        assert!(rec.train_loss.is_nan());
        assert_eq!(rec.stragglers.len(), 5, "whole cluster late (N_m = 5)");
        assert!(rec.net_s > 0.0);
    }
}

#[test]
fn generous_deadline_matches_no_deadline_run() {
    // A deadline nothing can miss must not perturb the run in any way.
    let Some(e) = engine() else { return };
    let run = |deadline_s: f64| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 4;
        cfg.deadline_s = deadline_s;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().data.clone(), rep)
    };
    let (state_none, rep_none) = run(0.0);
    let (state_slack, rep_slack) = run(1e9);
    assert_eq!(state_none, state_slack);
    assert_eq!(rep_none.total_byte_hops, rep_slack.total_byte_hops);
    for (a, b) in rep_none
        .metrics
        .rounds
        .iter()
        .zip(&rep_slack.metrics.rounds)
    {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert!(b.stragglers.is_empty());
    }
}

#[test]
fn edgeflow_latency_trains_end_to_end() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowLatency);
    cfg.topology = TopologyKind::Hybrid;
    cfg.rounds = 12;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert_eq!(report.algorithm, "edgeflow_latency");
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy > 0.1);
    // the tour visits every cluster in each 4-round cycle
    for cycle in 0..3 {
        let mut seen: Vec<usize> = report.metrics.rounds
            [cycle * 4..cycle * 4 + 4]
            .iter()
            .map(|r| r.cluster)
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "cycle {cycle}");
    }
}

#[test]
fn dropout_half_still_trains() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 20;
    cfg.dropout = 0.5;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_ne!(r.state().data, before);
    // Half the cluster vanishing every round slows learning; require the
    // loss trend (quarter-means over surviving rounds) to point down.
    let losses: Vec<f64> = report
        .metrics
        .rounds
        .iter()
        .map(|r| r.train_loss)
        .filter(|l| !l.is_nan())
        .collect();
    let q = (losses.len() / 4).max(1);
    let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
    let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
    assert!(tail < head, "loss {head:.4} -> {tail:.4} under dropout");
    // fewer uploads than the dropout-free run
    let mut full = tiny_cfg(Algorithm::EdgeFlowSeq);
    full.rounds = 20;
    let full_rep = Runner::with_engine(e, full).unwrap().run().unwrap();
    assert!(report.total_byte_hops < full_rep.total_byte_hops);
}

#[test]
fn fig4_results_identical_at_env_worker_count() {
    // Engine-free (pure coordination), so this runs in CI and gives the
    // workers={1,2} matrix real teeth: the suite-level cell pool must be
    // bit-invariant in EDGEFLOW_TEST_WORKERS even when every
    // artifact-gated test above skips.
    use edgeflow::fl::experiments::fig4;
    let algs = [
        Algorithm::FedAvg,
        Algorithm::HierFl,
        Algorithm::EdgeFlowSeq,
        Algorithm::EdgeFlowLatency,
    ];
    let (_, seq) = fig4(50_000, 4, 3, 10, &algs, 0, 1).unwrap();
    let (_, par) = fig4(50_000, 4, 3, 10, &algs, 0, env_workers()).unwrap();
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.topology, b.topology);
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(
            a.byte_hops_per_round.to_bits(),
            b.byte_hops_per_round.to_bits(),
            "{:?}/{:?}",
            a.topology,
            a.algorithm
        );
        assert_eq!(a.vs_fedavg.to_bits(), b.vs_fedavg.to_bits());
        assert_eq!(a.round_latency_s.to_bits(), b.round_latency_s.to_bits());
        assert_eq!(
            a.participants_per_round.to_bits(),
            b.participants_per_round.to_bits()
        );
    }
}

/// The deterministic half of two reports must agree bit-for-bit.
/// Wall-clock phase timings (`train_s`/`aggregate_s`/`phase_seconds`)
/// are excluded by nature — they measure this process, not the run.
fn assert_reports_bit_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.name, b.name);
    assert_eq!(a.algorithm, b.algorithm);
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.best_accuracy.to_bits(), b.best_accuracy.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.total_byte_hops, b.total_byte_hops);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.metrics.rounds.len(), b.metrics.rounds.len());
    for (x, y) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.cluster, y.cluster, "round {}", x.round);
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "round {}",
            x.round
        );
        assert_eq!(x.test_accuracy.to_bits(), y.test_accuracy.to_bits());
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.comm_byte_hops, y.comm_byte_hops);
        assert_eq!(x.net_s.to_bits(), y.net_s.to_bits(), "round {}", x.round);
        assert_eq!(x.clock_s.to_bits(), y.clock_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stragglers, y.stragglers);
        assert_eq!(x.deferred, y.deferred);
    }
}

#[test]
fn checkpoint_then_resume_is_bit_identical_to_uninterrupted() {
    // The session API's headline contract, across algorithm families:
    // run A straight through; run B steps to round 3, checkpoints
    // (through the serialized JSON, like a checkpoint file), is rebuilt
    // via Runner::resume, and finishes — reports and final model must
    // agree bit-for-bit.  Dropout exercises the RNG stream, a deadline
    // + defer the straggler pool, edgeflow_latency the persistent-DES
    // probes and tour state.
    let Some(e) = engine() else { return };
    for (alg, topo, deadline, policy) in [
        (
            Algorithm::EdgeFlowSeq,
            TopologyKind::Simple,
            1e-9,
            StragglerPolicy::Defer,
        ),
        (
            Algorithm::EdgeFlowLatency,
            TopologyKind::Hybrid,
            0.0,
            StragglerPolicy::Drop,
        ),
        (Algorithm::HierFl, TopologyKind::Simple, 0.0, StragglerPolicy::Drop),
    ] {
        let mk = || {
            let mut cfg = tiny_cfg(alg);
            cfg.topology = topo;
            cfg.rounds = if alg == Algorithm::HierFl { 4 } else { 6 };
            cfg.dropout = 0.2;
            cfg.deadline_s = deadline;
            cfg.straggler_policy = policy;
            cfg.eval_every = 2;
            cfg
        };
        let mut whole = Runner::with_engine(e.clone(), mk()).unwrap();
        let ref_report = whole.run().unwrap();

        let mut first = Runner::with_engine(e.clone(), mk()).unwrap();
        for _ in 0..3 {
            first.step().unwrap();
        }
        let ck = first.checkpoint().unwrap();
        let text = ck.to_json().pretty();
        let ck2 = RunnerCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(ck2.cursor, 3);
        let mut resumed = Runner::resume(e.clone(), &ck2).unwrap();
        assert_eq!(resumed.round(), 3, "{alg:?}");
        assert_eq!(
            resumed.net_clock_s().to_bits(),
            first.net_clock_s().to_bits(),
            "{alg:?}: restored DES clock"
        );
        let report = resumed.run().unwrap();
        assert_reports_bit_identical(&ref_report, &report);
        assert_eq!(
            whole.state().data,
            resumed.state().data,
            "{alg:?}: final model state after resume"
        );
    }
}

#[test]
fn restore_rejects_a_different_config() {
    let Some(e) = engine() else { return };
    let mut r = Runner::with_engine(e.clone(), tiny_cfg(Algorithm::EdgeFlowSeq))
        .unwrap();
    r.step().unwrap();
    let ck = r.checkpoint().unwrap();
    let mut other_cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    other_cfg.seed = 99;
    let mut other = Runner::with_engine(e, other_cfg).unwrap();
    assert!(other.restore(&ck).is_err(), "config mismatch must be typed");
}

/// Observer that records which hooks fired, in order.
struct RecordingObserver(Arc<Mutex<Vec<String>>>);

impl RoundObserver for RecordingObserver {
    fn on_plan(&mut self, t: usize, _plan: &RoundPlan, _ctl: &mut RoundControl) {
        self.0.lock().unwrap().push(format!("plan:{t}"));
    }
    fn on_comm(
        &mut self,
        t: usize,
        _comm: &RoundComm,
        _net_s: f64,
        _stragglers: &[usize],
        _ctl: &mut RoundControl,
    ) {
        self.0.lock().unwrap().push(format!("comm:{t}"));
    }
    fn on_aggregate(&mut self, t: usize, _state: &ModelState, _ctl: &mut RoundControl) {
        self.0.lock().unwrap().push(format!("aggregate:{t}"));
    }
    fn on_round_end(
        &mut self,
        t: usize,
        outcome: &RoundOutcome,
        _ctl: &mut RoundControl,
    ) {
        let tag = if outcome.is_lost() { "lost" } else { "end" };
        self.0.lock().unwrap().push(format!("{tag}:{t}"));
    }
}

#[test]
fn observer_callbacks_fire_in_phase_order() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 2;
    let calls = Arc::new(Mutex::new(Vec::new()));
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    r.add_observer(Box::new(RecordingObserver(calls.clone())));
    r.run().unwrap();
    assert_eq!(
        *calls.lock().unwrap(),
        vec![
            "plan:0",
            "comm:0",
            "aggregate:0",
            "end:0",
            "plan:1",
            "comm:1",
            "aggregate:1",
            "end:1"
        ]
    );

    // An all-dropped round skips comm and aggregate but still closes.
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 1;
    cfg.dropout = 1.0;
    let calls = Arc::new(Mutex::new(Vec::new()));
    let mut r = Runner::with_engine(e, cfg).unwrap();
    r.add_observer(Box::new(RecordingObserver(calls.clone())));
    r.run().unwrap();
    assert_eq!(*calls.lock().unwrap(), vec!["plan:0", "lost:0"]);
}

/// Observer that stops the session once `limit` rounds have run.
struct StopAfter(usize);

impl RoundObserver for StopAfter {
    fn on_round_end(&mut self, t: usize, _o: &RoundOutcome, ctl: &mut RoundControl) {
        if t + 1 >= self.0 {
            ctl.request_stop();
        }
    }
}

#[test]
fn observer_can_stop_the_session_early() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 8;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    r.add_observer(Box::new(StopAfter(3)));
    let report = r.run().unwrap();
    assert!(r.is_done());
    assert_eq!(report.rounds, 3);
    assert_eq!(report.metrics.rounds.len(), 3);
    assert!(r.step().is_err(), "stepping a stopped session is a typed error");
}

/// Observer that switches the deadline on from round `from` (per-cluster
/// adaptive deadlines are this, with a policy instead of a constant).
struct DeadlineFromRound {
    from: usize,
    deadline_s: f64,
}

impl RoundObserver for DeadlineFromRound {
    fn on_plan(&mut self, t: usize, _plan: &RoundPlan, ctl: &mut RoundControl) {
        if t == self.from {
            ctl.set_deadline_s(self.deadline_s);
        }
    }
}

#[test]
fn observer_deadline_override_applies_to_the_planned_round() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    r.add_observer(Box::new(DeadlineFromRound { from: 1, deadline_s: 1e-9 }));
    let report = r.run().unwrap();
    let recs = &report.metrics.rounds;
    assert!(recs[0].stragglers.is_empty(), "no deadline at round 0");
    assert!(!recs[0].train_loss.is_nan());
    for rec in &recs[1..] {
        assert_eq!(
            rec.stragglers.len(),
            5,
            "round {} under the 1e-9 deadline (N_m = 5)",
            rec.round
        );
        assert!(
            rec.train_loss.is_nan(),
            "drop policy: all-straggled rounds are lost"
        );
    }
}

#[test]
fn defer_policy_folds_late_updates_into_the_next_round() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.deadline_s = 1e-9; // every upload is late
    cfg.straggler_policy = StragglerPolicy::Defer;
    let mut r = Runner::with_engine(e.clone(), cfg.clone()).unwrap();

    // Probe the expected fold with a second runner sharing the engine:
    // cluster 0's round-0 updates against the initial state, reduced
    // with their Eq. 3 sample weights in client-id order.
    let probe = Runner::with_engine(e, cfg).unwrap();
    let members = probe.fed.cluster_members(0);
    let mut weighted = Vec::new();
    let mut loss_terms: Vec<(f64, f64)> = Vec::new();
    for &id in &members {
        let (s, loss) = probe.local_update_for(id, 0).unwrap();
        loss_terms.push((probe.client_weight(id), loss as f64));
        weighted.push((probe.client_weight(id), s));
    }
    let (_w, expected) = reduce_states_weighted(weighted).unwrap();

    // Round 0: everyone straggles and nothing is pending — the round is
    // lost, but (unlike drop) the late updates are held, not discarded.
    let out0 = r.step().unwrap();
    assert!(out0.is_lost());
    assert_eq!(out0.record().stragglers, members);
    assert!(out0.record().deferred.is_empty());
    assert_eq!(r.pending_deferrals(), members);

    // Round 1: cluster 1 trains (and straggles again) while round 0's
    // late updates fold in — the model moves exactly to their Eq. 3
    // reduction, one round late.
    let out1 = r.step().unwrap();
    assert!(!out1.is_lost());
    assert_eq!(out1.record().deferred, members);
    assert_eq!(out1.record().stragglers, probe.fed.cluster_members(1));
    assert_eq!(
        r.state().data,
        expected.data,
        "fold must equal the Eq. 3 reduction of the deferred updates"
    );
    let wsum: f64 = loss_terms.iter().map(|(w, _)| w).sum();
    let want_loss = loss_terms.iter().map(|(w, l)| w * l).sum::<f64>() / wsum;
    assert_eq!(
        out1.record().train_loss.to_bits(),
        want_loss.to_bits(),
        "round 1's weighted loss covers exactly the folded operands"
    );

    // Round 2 folds cluster 1's updates in turn; every straggle event
    // folds at most once (one pending update per client, ever).
    let out2 = r.step().unwrap();
    assert_eq!(out2.record().deferred, probe.fed.cluster_members(1));
    assert_eq!(r.pending_deferrals(), probe.fed.cluster_members(2));
}

#[test]
fn metrics_csv_observer_exports_live_rows() {
    // The built-in live exporter (and `train --live-csv`): after every
    // round the file holds all rounds so far, so a crash mid-run leaves
    // an inspectable curve behind.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    let path = std::env::temp_dir().join("edgeflow_live_metrics_test.csv");
    let path_s = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);
    let mut r = Runner::with_engine(e, cfg).unwrap();
    r.add_observer(Box::new(MetricsCsvObserver::new(&path_s)));
    r.step().unwrap();
    let after_one = std::fs::read_to_string(&path).unwrap();
    assert_eq!(after_one.lines().count(), 2, "header + round 0");
    r.run().unwrap();
    let after_all = std::fs::read_to_string(&path).unwrap();
    assert_eq!(after_all.lines().count(), 4, "header + all 3 rounds");
    assert!(after_all.starts_with("round,"), "{after_all}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn defer_never_double_counts_an_on_time_client() {
    // HierFl trains every client every round.  Round 0 runs under an
    // impossible deadline (every update deferred); round 1's deadline is
    // lifted, so every client delivers a *fresh* on-time update while
    // its stale round-0 update is still pending — the stale entries are
    // superseded and must NOT fold next to the fresh ones (that would
    // double the client's Eq. 3 weight in one reduction).
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::HierFl);
    cfg.rounds = 2;
    cfg.straggler_policy = StragglerPolicy::Defer;
    let mut r = Runner::with_engine(e.clone(), cfg.clone()).unwrap();
    r.add_observer(Box::new(DeadlineFromRound { from: 0, deadline_s: 1e-9 }));
    r.add_observer(Box::new(DeadlineFromRound { from: 1, deadline_s: 0.0 }));

    let out0 = r.step().unwrap();
    assert!(out0.is_lost());
    assert_eq!(r.pending_deferrals().len(), 20, "all clients deferred");

    let out1 = r.step().unwrap();
    assert!(!out1.is_lost());
    assert!(out1.record().stragglers.is_empty());
    assert!(
        out1.record().deferred.is_empty(),
        "stale updates superseded by on-time ones must not fold"
    );
    assert!(
        r.pending_deferrals().is_empty(),
        "superseded entries are discarded, not re-queued"
    );

    // Round 1's model must equal the plain Eq. 3 aggregation of the
    // fresh round-1 updates alone (trained against the unchanged
    // initial state): per-cluster partials, then the cross-cluster
    // reduction — no stale weight anywhere.
    let probe = Runner::with_engine(e, cfg).unwrap();
    let mut partials = Vec::new();
    for m in 0..4 {
        let weighted: Vec<(f64, ModelState)> = probe
            .fed
            .cluster_members(m)
            .iter()
            .map(|&id| {
                (probe.client_weight(id), probe.local_update_for(id, 1).unwrap().0)
            })
            .collect();
        partials.push(reduce_states_weighted(weighted).unwrap());
    }
    let (_w, expected) = reduce_states_weighted(partials).unwrap();
    assert_eq!(r.state().data, expected.data, "no double-counted client");
}

#[test]
fn defer_without_deadline_changes_nothing() {
    // straggler_policy=defer with no deadline (or no stragglers) must be
    // a strict no-op: bit-identical to the drop-policy run.
    let Some(e) = engine() else { return };
    let run_with = |policy: StragglerPolicy| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 4;
        cfg.straggler_policy = policy;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().data.clone(), rep)
    };
    let (state_drop, rep_drop) = run_with(StragglerPolicy::Drop);
    let (state_defer, rep_defer) = run_with(StragglerPolicy::Defer);
    assert_eq!(state_drop, state_defer);
    assert_reports_bit_identical(&rep_drop, &rep_defer);
}

#[test]
fn metrics_account_every_round() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 5;
    cfg.eval_every = 2;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert_eq!(report.metrics.rounds.len(), 5);
    // evaluated at rounds 1, 3, 4 (eval_every=2 plus final)
    let evals: Vec<usize> = report
        .metrics
        .rounds
        .iter()
        .filter(|r| !r.test_accuracy.is_nan())
        .map(|r| r.round)
        .collect();
    assert_eq!(evals, vec![1, 3, 4]);
    // every round moved bytes
    assert!(report.metrics.rounds.iter().all(|r| r.comm_byte_hops > 0));
}
