//! End-to-end coordinator tests over the real artifacts: the full
//! Runner loop (data -> PJRT local updates -> aggregation -> migration ->
//! eval) for every algorithm.

use std::sync::Arc;

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, ExperimentConfig, TopologyKind,
};
use edgeflow::fl::runner::Runner;
use edgeflow::runtime::executor::Engine;

fn engine() -> Option<Arc<Engine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Engine::load("artifacts").expect("engine")))
}

fn tiny_cfg(alg: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("test_{}", alg.name()),
        algorithm: alg,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 20,
        clusters: 4,
        local_steps: 5,
        rounds: 8,
        samples_per_client: 80,
        test_samples: 200,
        eval_every: 4,
        seed: 3,
        lr: 2e-3, // short runs: push Adam a little harder than the paper default
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_algorithm_trains_and_improves() {
    let Some(e) = engine() else { return };
    // Random init on 10 classes ~= 10% accuracy; a short run must beat it
    // clearly for the averaging algorithms.
    for alg in [
        Algorithm::FedAvg,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
        Algorithm::HierFl,
    ] {
        let mut cfg = tiny_cfg(alg);
        cfg.rounds = 40;
        if alg == Algorithm::HierFl {
            cfg.rounds = 6; // trains all clients per round; keep it short
        }
        let report = Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap();
        assert!(
            report.final_accuracy > 0.2,
            "{}: accuracy {} too low",
            alg.name(),
            report.final_accuracy
        );
        assert!(report.final_loss.is_finite());
        assert_eq!(report.metrics.rounds.len(), report.rounds);
        // training must actually reduce the loss; compare quarter-means
        // since per-round loss is noisy under client resampling
        let losses: Vec<f64> =
            report.metrics.rounds.iter().map(|r| r.train_loss).collect();
        let q = (losses.len() / 4).max(1);
        let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
        let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
        assert!(tail < head, "{}: loss {head:.4} -> {tail:.4}", alg.name());
    }
}

#[test]
fn seqfl_runs_without_aggregation() {
    // Under IID data the sequential chain learns; under heavy non-IID it
    // exhibits the catastrophic-forgetting pathology the paper cites as
    // motivation — both behaviours are exercised here.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::SeqFl);
    cfg.distribution = Distribution::Iid;
    cfg.rounds = 20;
    cfg.lr = 1e-3;
    let report = Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy > 0.2, "iid seqfl: {}", report.final_accuracy);

    // Non-IID: the model chases each client's 1-2 classes; accuracy stays
    // far below the averaging algorithms at the same budget.
    let mut cfg = tiny_cfg(Algorithm::SeqFl);
    cfg.distribution = Distribution::NonIid { major_fraction: 1.0 };
    cfg.rounds = 20;
    cfg.lr = 1e-3;
    let forgetful = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert!(forgetful.final_loss.is_finite());
    assert!(
        forgetful.final_accuracy < report.final_accuracy,
        "non-IID seqfl should trail IID seqfl ({} vs {})",
        forgetful.final_accuracy,
        report.final_accuracy
    );
}

#[test]
fn single_cluster_edgeflow_equals_fedavg_full_participation() {
    // With M = 1, EdgeFLow's active cluster is all clients and FedAvg's
    // sample (N_m = N) is also all clients: identical participant sets,
    // identical batches, identical uniform aggregation => identical model.
    let Some(e) = engine() else { return };
    let mut a = tiny_cfg(Algorithm::EdgeFlowSeq);
    a.clusters = 1;
    a.rounds = 3;
    let mut b = tiny_cfg(Algorithm::FedAvg);
    b.clusters = 1;
    b.rounds = 3;
    let mut ra = Runner::with_engine(e.clone(), a).unwrap();
    let rep_a = ra.run().unwrap();
    let mut rb = Runner::with_engine(e, b).unwrap();
    let rep_b = rb.run().unwrap();
    assert_eq!(ra.state().data, rb.state().data, "models must be identical");
    assert_eq!(rep_a.final_accuracy, rep_b.final_accuracy);
}

#[test]
fn runs_are_seed_deterministic() {
    let Some(e) = engine() else { return };
    let mk = || tiny_cfg(Algorithm::EdgeFlowRand);
    let mut r1 = Runner::with_engine(e.clone(), mk()).unwrap();
    let a = r1.run().unwrap();
    let mut r2 = Runner::with_engine(e.clone(), mk()).unwrap();
    let b = r2.run().unwrap();
    assert_eq!(r1.state().data, r2.state().data);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_byte_hops, b.total_byte_hops);
    // Different seed must actually change the run.
    let mut cfg = mk();
    cfg.seed = 99;
    let mut r3 = Runner::with_engine(e, cfg).unwrap();
    r3.run().unwrap();
    assert_ne!(r1.state().data, r3.state().data);
}

#[test]
fn edgeflow_communicates_less_than_fedavg_on_deep_topology() {
    let Some(e) = engine() else { return };
    let run = |alg: Algorithm| {
        let mut cfg = tiny_cfg(alg);
        cfg.topology = TopologyKind::DepthLinear;
        cfg.rounds = 6;
        Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap()
    };
    let fedavg = run(Algorithm::FedAvg);
    let edge = run(Algorithm::EdgeFlowSeq);
    assert!(
        (edge.total_byte_hops as f64) < 0.5 * fedavg.total_byte_hops as f64,
        "edgeflow {} vs fedavg {}",
        edge.total_byte_hops,
        fedavg.total_byte_hops
    );
}

#[test]
fn cnn_variant_runs_one_round() {
    let Some(e) = engine() else { return };
    let cfg = ExperimentConfig {
        name: "cnn_smoke".into(),
        algorithm: Algorithm::EdgeFlowSeq,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::Iid,
        model: "fashion_cnn_slim_fast".into(),
        clients: 4,
        clusters: 2,
        local_steps: 5,
        rounds: 1,
        samples_per_client: 64,
        test_samples: 100,
        eval_every: 1,
        seed: 0,
        ..ExperimentConfig::default()
    };
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy >= 0.0);
}

#[test]
fn config_artifact_cross_validation() {
    let Some(e) = engine() else { return };
    // K without an artifact
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.local_steps = 3;
    assert!(Runner::with_engine(e.clone(), cfg).is_err());
    // wrong dataset for the model
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.dataset = DatasetKind::SynthCifar; // model stays fashion_mlp
    assert!(Runner::with_engine(e.clone(), cfg).is_err());
    // batch size mismatch
    let mut cfg = tiny_cfg(Algorithm::FedAvg);
    cfg.batch_size = 32;
    cfg.samples_per_client = 64;
    assert!(Runner::with_engine(e, cfg).is_err());
}

#[test]
fn edgeflow_hop_minimizes_migration_cost() {
    // On the depth-linear chain, the hop-aware circuit's migrations should
    // cost no more than the sequential circuit's (both visit every cluster
    // each cycle; hop-aware orders by BS proximity).
    let Some(e) = engine() else { return };
    let run = |alg: Algorithm| {
        let mut cfg = tiny_cfg(alg);
        cfg.topology = TopologyKind::DepthLinear;
        cfg.rounds = 12;
        Runner::with_engine(e.clone(), cfg).unwrap().run().unwrap()
    };
    let hop = run(Algorithm::EdgeFlowHop);
    let seq = run(Algorithm::EdgeFlowSeq);
    assert!(hop.final_accuracy > 0.1);
    assert!(
        hop.total_byte_hops <= seq.total_byte_hops,
        "hop-aware {} vs sequential {}",
        hop.total_byte_hops,
        seq.total_byte_hops
    );
}

#[test]
fn dropout_one_keeps_model_frozen() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 4;
    cfg.dropout = 1.0;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_eq!(r.state().data, before, "all-dropped rounds must not move the model");
    assert_eq!(report.total_byte_hops, 0);
    assert_eq!(report.metrics.rounds.len(), 4);
    // Every lost round must still be recorded: NaN losses, zero traffic,
    // zero simulated network time — and the run must not error out.
    for rec in &report.metrics.rounds {
        assert!(rec.train_loss.is_nan(), "round {} has a loss", rec.round);
        assert!(rec.test_loss.is_nan());
        assert_eq!(rec.comm_byte_hops, 0);
        assert_eq!(rec.net_s, 0.0);
    }
}

#[test]
fn weighted_aggregation_follows_sample_counts() {
    // The Eq. 3 bugfix: clients weigh into the cluster aggregate by their
    // actual |D_n|, not uniformly.  Unbalance a 2-client cluster, compose
    // the expected aggregate from per-client probes, and check the round
    // loop reproduces it bit-for-bit (and diverges from uniform weights).
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.clients = 2;
    cfg.clusters = 1;
    cfg.rounds = 1;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    r.fed.clients[1].samples.truncate(16); // 80 vs 16 samples
    assert_eq!(r.client_weight(0), 80.0);
    assert_eq!(r.client_weight(1), 16.0);
    let (s0, _) = r.local_update_for(0, 0).unwrap();
    let (s1, _) = r.local_update_for(1, 0).unwrap();
    let (_, expected) = edgeflow::fl::aggregate::reduce_states_weighted(vec![
        (80.0, s0.clone()),
        (16.0, s1.clone()),
    ])
    .unwrap();
    let (_, uniform) =
        edgeflow::fl::aggregate::reduce_states_weighted(vec![(1.0, s0), (1.0, s1)])
            .unwrap();
    r.run().unwrap();
    assert_eq!(r.state().data, expected.data, "sample-count weighting");
    assert_ne!(r.state().data, uniform.data, "must not be uniform");
}

#[test]
fn worker_count_never_changes_results() {
    // The determinism contract of the parallel round loop: workers=N is
    // byte-identical to workers=1 — model state, per-round losses,
    // accuracies and byte-hops.  Dropout is on so the failure-injection
    // stream is exercised too (it is drawn on the main thread, before the
    // fan-out, and must not depend on worker scheduling).
    let Some(e) = engine() else { return };
    let run_with = |workers: usize| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 6;
        cfg.dropout = 0.25;
        cfg.workers = workers;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let report = r.run().unwrap();
        (r.state().data.clone(), report)
    };
    let (state1, rep1) = run_with(1);
    for workers in [2usize, 4, 0] {
        let (state_n, rep_n) = run_with(workers);
        assert_eq!(state_n, state1, "state diverged at workers={workers}");
        assert_eq!(rep_n.total_byte_hops, rep1.total_byte_hops);
        assert_eq!(
            rep_n.final_accuracy.to_bits(),
            rep1.final_accuracy.to_bits(),
            "accuracy diverged at workers={workers}"
        );
        for (a, b) in rep_n.metrics.rounds.iter().zip(&rep1.metrics.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.comm_byte_hops, b.comm_byte_hops);
        }
    }
}

#[test]
fn rounds_report_simulated_network_time() {
    // net_s used to be hardcoded 0.0; every round that moves bytes must
    // now carry a positive simulated transfer makespan.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 4;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    for rec in &report.metrics.rounds {
        assert!(rec.comm_byte_hops > 0);
        assert!(rec.net_s > 0.0, "round {} has no net time", rec.round);
    }
    assert!(report.metrics.total_net_s() > 0.0);
}

#[test]
fn clock_s_accumulates_round_makespans() {
    // The persistent DES: each round opens at the previous round's clock,
    // so clock_s is the running sum of per-round makespans — the simulated
    // wall-clock axis for time-resolved convergence curves.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 5;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let report = r.run().unwrap();
    let mut expected = 0.0;
    for rec in &report.metrics.rounds {
        expected += rec.net_s;
        assert!(
            (rec.clock_s - expected).abs() < 1e-9,
            "round {}: clock {} vs accumulated {}",
            rec.round,
            rec.clock_s,
            expected
        );
    }
    assert!((r.net_clock_s() - expected).abs() < 1e-9);
}

#[test]
fn net_s_monotone_in_model_size() {
    // Same federation, same schedule, same transfers — only the model's
    // wire bytes differ, so per-round simulated network time must not
    // decrease with model size.
    let Some(e) = engine() else { return };
    let run = |model: &str| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.model = model.into();
        cfg.clients = 4;
        cfg.clusters = 2;
        cfg.rounds = 3;
        cfg.samples_per_client = 64;
        cfg.test_samples = 100;
        cfg.eval_every = 3;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let bytes = r.state().param_bytes();
        (bytes, r.run().unwrap())
    };
    let (bytes_a, rep_a) = run("fashion_mlp");
    let (bytes_b, rep_b) = run("fashion_cnn_slim_fast");
    let ((_, small), (b_big, big)) = if bytes_a <= bytes_b {
        ((bytes_a, rep_a), (bytes_b, rep_b))
    } else {
        ((bytes_b, rep_b), (bytes_a, rep_a))
    };
    for (s, b) in small.metrics.rounds.iter().zip(&big.metrics.rounds) {
        assert!(
            b.net_s >= s.net_s,
            "round {}: {} bytes took {}s vs {}s",
            s.round,
            b_big,
            b.net_s,
            s.net_s
        );
    }
}

#[test]
fn all_dropped_rounds_leave_net_clock_unchanged() {
    // A lost round moves no bytes, so the persistent sim clock must not
    // advance — the simulated time axis only runs when traffic flows.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.dropout = 1.0;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let report = r.run().unwrap();
    assert_eq!(r.net_clock_s(), 0.0);
    for rec in &report.metrics.rounds {
        assert_eq!(rec.net_s, 0.0);
        assert_eq!(rec.clock_s, 0.0);
        assert!(rec.stragglers.is_empty());
    }
}

#[test]
fn impossible_deadline_freezes_model_but_charges_traffic() {
    // deadline_s far below any physical delivery time: every upload is
    // late, every round records its cluster as stragglers, the model
    // never moves — but the (late) traffic is still charged and the sim
    // clock still advances.
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 3;
    cfg.deadline_s = 1e-9;
    let mut r = Runner::with_engine(e, cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_eq!(r.state().data, before, "all-straggled rounds must not train");
    assert!(report.total_byte_hops > 0, "late uploads still transmit");
    assert!(r.net_clock_s() > 0.0);
    for rec in &report.metrics.rounds {
        assert!(rec.train_loss.is_nan());
        assert_eq!(rec.stragglers.len(), 5, "whole cluster late (N_m = 5)");
        assert!(rec.net_s > 0.0);
    }
}

#[test]
fn generous_deadline_matches_no_deadline_run() {
    // A deadline nothing can miss must not perturb the run in any way.
    let Some(e) = engine() else { return };
    let run = |deadline_s: f64| {
        let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
        cfg.rounds = 4;
        cfg.deadline_s = deadline_s;
        let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
        let rep = r.run().unwrap();
        (r.state().data.clone(), rep)
    };
    let (state_none, rep_none) = run(0.0);
    let (state_slack, rep_slack) = run(1e9);
    assert_eq!(state_none, state_slack);
    assert_eq!(rep_none.total_byte_hops, rep_slack.total_byte_hops);
    for (a, b) in rep_none
        .metrics
        .rounds
        .iter()
        .zip(&rep_slack.metrics.rounds)
    {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert!(b.stragglers.is_empty());
    }
}

#[test]
fn edgeflow_latency_trains_end_to_end() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowLatency);
    cfg.topology = TopologyKind::Hybrid;
    cfg.rounds = 12;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert_eq!(report.algorithm, "edgeflow_latency");
    assert!(report.final_loss.is_finite());
    assert!(report.final_accuracy > 0.1);
    // the tour visits every cluster in each 4-round cycle
    for cycle in 0..3 {
        let mut seen: Vec<usize> = report.metrics.rounds
            [cycle * 4..cycle * 4 + 4]
            .iter()
            .map(|r| r.cluster)
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "cycle {cycle}");
    }
}

#[test]
fn dropout_half_still_trains() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 20;
    cfg.dropout = 0.5;
    let mut r = Runner::with_engine(e.clone(), cfg).unwrap();
    let before = r.state().data.clone();
    let report = r.run().unwrap();
    assert_ne!(r.state().data, before);
    // Half the cluster vanishing every round slows learning; require the
    // loss trend (quarter-means over surviving rounds) to point down.
    let losses: Vec<f64> = report
        .metrics
        .rounds
        .iter()
        .map(|r| r.train_loss)
        .filter(|l| !l.is_nan())
        .collect();
    let q = (losses.len() / 4).max(1);
    let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
    let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
    assert!(tail < head, "loss {head:.4} -> {tail:.4} under dropout");
    // fewer uploads than the dropout-free run
    let mut full = tiny_cfg(Algorithm::EdgeFlowSeq);
    full.rounds = 20;
    let full_rep = Runner::with_engine(e, full).unwrap().run().unwrap();
    assert!(report.total_byte_hops < full_rep.total_byte_hops);
}

#[test]
fn metrics_account_every_round() {
    let Some(e) = engine() else { return };
    let mut cfg = tiny_cfg(Algorithm::EdgeFlowSeq);
    cfg.rounds = 5;
    cfg.eval_every = 2;
    let report = Runner::with_engine(e, cfg).unwrap().run().unwrap();
    assert_eq!(report.metrics.rounds.len(), 5);
    // evaluated at rounds 1, 3, 4 (eval_every=2 plus final)
    let evals: Vec<usize> = report
        .metrics
        .rounds
        .iter()
        .filter(|r| !r.test_accuracy.is_nan())
        .map(|r| r.round)
        .collect();
    assert_eq!(evals, vec![1, 3, 4]);
    // every round moved bytes
    assert!(report.metrics.rounds.iter().all(|r| r.comm_byte_hops > 0));
}
