//! Integration tests over the real AOT artifacts: runtime + executor.
//!
//! These require `make artifacts` to have run (skipped gracefully
//! otherwise, mirroring the pytest suite's behavior).

use std::sync::Arc;

use edgeflow::data::dataset::Batch;
use edgeflow::runtime::executor::Engine;

fn engine() -> Option<Arc<Engine>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Engine::load("artifacts").expect("engine")))
}

fn batch_for(k: usize, b: usize, image: (usize, usize, usize), seed: u64) -> Batch {
    let (h, w, c) = image;
    let mut rng = edgeflow::rng::Rng::new(seed);
    Batch {
        x: (0..k * b * h * w * c).map(|_| rng.f32()).collect(),
        y: (0..k * b).map(|_| rng.below(10) as i32).collect(),
    }
}

#[test]
fn manifest_lists_expected_variants() {
    let Some(e) = engine() else { return };
    for v in ["fashion_mlp", "cifar_mlp", "fashion_cnn_slim"] {
        assert!(e.manifest.variants.contains_key(v), "missing variant {v}");
    }
    let v = e.manifest.variant("fashion_mlp").unwrap();
    assert_eq!(v.image, (28, 28, 1));
    assert_eq!(v.train_batch, 64);
    // MLP 784->128->64->10
    assert_eq!(v.param_count(), 784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
}

#[test]
fn init_state_loads_and_is_finite() {
    let Some(e) = engine() else { return };
    for opt in ["sgd", "adam"] {
        let s = e.init_state("fashion_mlp", opt).unwrap();
        assert!(s.is_finite());
        assert!(s.param_l2() > 0.0, "init params should not be all-zero");
    }
}

#[test]
fn local_update_changes_params_and_reports_loss() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "sgd", 1).unwrap();
    let s0 = e.init_state("fashion_mlp", "sgd").unwrap();
    let batch = batch_for(1, 64, lu.image, 7);
    let (s1, loss) = lu.run(&s0, &batch, 0.05).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // Roughly ln(10) for random init on 10 classes.
    assert!((1.0..4.0).contains(&loss), "loss {loss}");
    assert!(s1.param_dist2(&s0) > 0.0, "params must move");
    assert!(s1.is_finite());
}

#[test]
fn local_update_lr_zero_is_noop() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "sgd", 1).unwrap();
    let s0 = e.init_state("fashion_mlp", "sgd").unwrap();
    let batch = batch_for(1, 64, lu.image, 11);
    let (s1, _) = lu.run(&s0, &batch, 0.0).unwrap();
    let n = s0.layout.param_elems();
    assert_eq!(&s0.data[..n], &s1.data[..n]);
}

#[test]
fn local_update_is_deterministic() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "adam", 5).unwrap();
    let s0 = e.init_state("fashion_mlp", "adam").unwrap();
    let batch = batch_for(5, 64, lu.image, 13);
    let (a, la) = lu.run(&s0, &batch, 0.001).unwrap();
    let (b, lb) = lu.run(&s0, &batch, 0.001).unwrap();
    assert_eq!(la, lb);
    assert_eq!(a.data, b.data);
}

#[test]
fn adam_step_counter_advances_by_k() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "adam", 5).unwrap();
    let s0 = e.init_state("fashion_mlp", "adam").unwrap();
    let batch = batch_for(5, 64, lu.image, 17);
    let (s1, _) = lu.run(&s0, &batch, 0.001).unwrap();
    // adam_t is the last tensor in the layout.
    let t_idx = s1.layout.tensors.len() - 1;
    assert_eq!(s1.layout.tensors[t_idx].name, "adam_t");
    assert_eq!(s1.tensor(t_idx)[0], 5.0);
    assert_eq!(s0.tensor(t_idx)[0], 0.0);
}

#[test]
fn repeated_updates_on_one_batch_reduce_loss() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "sgd", 1).unwrap();
    let mut s = e.init_state("fashion_mlp", "sgd").unwrap();
    let batch = batch_for(1, 64, lu.image, 19);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..20 {
        let (s2, loss) = lu.run(&s, &batch, 0.05).unwrap();
        s = s2;
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.8,
        "memorizing one batch must reduce loss ({first} -> {last})"
    );
}

#[test]
fn eval_counts_are_consistent() {
    let Some(e) = engine() else { return };
    let ev = e.eval("fashion_mlp", "sgd").unwrap();
    let s = e.init_state("fashion_mlp", "sgd").unwrap();
    let (h, w, c) = ev.image;
    let mut rng = edgeflow::rng::Rng::new(23);
    let batch = Batch {
        x: (0..ev.b * h * w * c).map(|_| rng.f32()).collect(),
        y: (0..ev.b).map(|_| rng.below(10) as i32).collect(),
    };
    let (loss_sum, correct) = ev.run(&s, &batch).unwrap();
    assert!(loss_sum > 0.0);
    assert!((0.0..=ev.b as f32).contains(&correct));
}

#[test]
fn eval_dataset_handles_partial_tail() {
    let Some(e) = engine() else { return };
    let ev = e.eval("fashion_mlp", "sgd").unwrap();
    let s = e.init_state("fashion_mlp", "sgd").unwrap();
    let gen = edgeflow::data::synth::SynthGen::new(
        edgeflow::config::DatasetKind::SynthFashion,
        3,
    );
    // 130 samples: one full batch of 100 + padded tail of 30.
    let ds = gen.test_set(130);
    let (loss, acc) = ev.run_dataset(&s, &ds).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn batch_shape_mismatch_is_rejected() {
    let Some(e) = engine() else { return };
    let lu = e.local_update("fashion_mlp", "sgd", 5).unwrap();
    let s = e.init_state("fashion_mlp", "sgd").unwrap();
    let bad = batch_for(1, 64, lu.image, 29); // K=1 batch for a K=5 exe
    assert!(lu.run(&s, &bad, 0.01).is_err());
}

#[test]
fn missing_artifact_errors_cleanly() {
    let Some(e) = engine() else { return };
    assert!(e.local_update("fashion_mlp", "adam", 99).is_err());
    assert!(e.local_update("no_such_model", "sgd", 1).is_err());
    assert!(e.init_state("fashion_mlp", "rmsprop").is_err());
}
