//! Tier-1 gate for the determinism lint: plain `cargo test -q` runs
//! the full-tree `edgeflow-lint` sweep, so a contract violation fails
//! the build even without the dedicated CI job.
//!
//! Exit-code contract of the `edgeflow-lint` binary (the library API
//! used here returns the same diagnostics): 0 = clean, 1 = violations
//! (printed as `file:line:rule: message`), 2 = usage/I-O error.

use std::path::Path;

use edgeflow_lint::{lint_source, lint_sources, lint_tree, Rule};

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is rust/; the repo root is its parent.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ sits inside the repo root")
}

#[test]
fn repo_tree_is_lint_clean() {
    let report = lint_tree(repo_root()).expect("tree scan failed");
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        report.clean(),
        "determinism-lint violations (fix or add a justified \
         lint:allow pragma):\n{}",
        rendered.join("\n")
    );
    // Sanity: the sweep actually visited the tree (src + tests +
    // benches + examples + the lint's own sources).
    assert!(
        report.files_scanned >= 30,
        "scan looks truncated: only {} files visited",
        report.files_scanned
    );
}

#[test]
fn every_suppression_in_tree_carries_a_reason() {
    // Unjustified pragmas surface as `pragma` diagnostics, so a clean
    // tree implies every suppression is explained.  Check the count
    // is nonzero: the fl/runtime unwrap sweep is expected to rely on
    // justified pragmas, and this guards against the engine silently
    // ignoring them.
    let report = lint_tree(repo_root()).expect("tree scan failed");
    assert!(
        !report.suppressed.is_empty(),
        "expected at least one justified suppression in the tree"
    );
}

#[test]
fn seeded_violation_is_caught() {
    // A NaN-unsound ordering smuggled into an aggregation module must
    // produce a diagnostic — this is the regression test that the
    // gate actually gates.
    let bad = "pub fn sel(v: &mut Vec<f32>) {\n    \
               v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let out = lint_source("rust/src/fl/aggregate.rs", bad);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::FloatOrdering),
        "seeded partial_cmp went undetected"
    );

    let clock = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    let out = lint_source("rust/src/netsim/sim.rs", clock);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::WallClockInSim),
        "seeded wall-clock read went undetected"
    );
}

#[test]
fn seeded_contract_violations_are_caught() {
    // The cross-file rules run in the whole-set pipeline (`lint_sources`
    // / `lint_tree`); each seeded drift below must fail the gate.

    // checkpoint-parity: `stream` never reaches either side of the
    // RngState round-trip.
    let rng = "pub struct RngState {\n    pub seed: u64,\n    pub stream: u64,\n}\n\
               impl RngState {\n    pub fn to_json(&self) -> String {\n        \
               emit(\"seed\", self.seed)\n    }\n    \
               pub fn from_json(s: &str) -> RngState {\n        \
               defaults(read(s, \"seed\"))\n    }\n}\n";
    let out = lint_sources(&[("rust/src/rng/mod.rs", rng)]);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::CheckpointParity),
        "seeded checkpoint drift went undetected"
    );

    // csv-schema-parity: header and record disagree on a column name.
    let metrics = "pub struct RoundRecord {\n    pub round: usize,\n    pub loss: f64,\n}\n\
                   pub const METRICS_CSV_HEADER: &str = \"round lost\";\n\
                   impl RoundRecord {\n    \
                   pub fn to_ckpt_json(&self) -> String {\n        \
                   pair(self.round, self.loss)\n    }\n    \
                   pub fn from_ckpt_json(s: &str) -> RoundRecord {\n        \
                   RoundRecord { round: r(s, \"round\"), loss: r(s, \"loss\") }\n    }\n    \
                   pub fn csv_fields(&self) -> Vec<String> {\n        \
                   vec![n(self.round), n(self.loss)]\n    }\n}\n";
    let out = lint_sources(&[("rust/src/metrics/mod.rs", metrics)]);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::CsvSchemaParity),
        "seeded CSV schema drift went undetected"
    );

    // config-surface-parity: a config field with no CLI override arm.
    let cfg = "pub struct ExperimentConfig {\n    pub rounds: usize,\n    pub fresh: f64,\n}\n\
               impl ExperimentConfig {\n    pub fn to_json(&self) -> String {\n        \
               emit(\"rounds\", self.rounds, \"fresh\", self.fresh)\n    }\n    \
               pub fn from_json(s: &str) -> ExperimentConfig {\n        \
               build(r(s, \"rounds\"), r(s, \"fresh\"))\n    }\n}\n";
    let cli = "pub fn apply_overrides(mut cfg: ExperimentConfig) -> ExperimentConfig {\n    \
               cfg.rounds = flag(\"rounds\");\n    cfg\n}\n";
    let out = lint_sources(&[
        ("rust/src/config/mod.rs", cfg),
        ("rust/src/cli/mod.rs", cli),
    ]);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::ConfigSurfaceParity),
        "seeded config-surface gap went undetected"
    );

    // stale-pragma: an allow whose guarded pattern is gone.
    let stale = "pub fn first(v: &[f32]) -> f32 {\n    \
                 // lint:allow(unwrap-in-library): checked upstream.\n    v[0]\n}\n";
    let out = lint_sources(&[("rust/src/fl/fixture.rs", stale)]);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::StalePragma),
        "seeded stale pragma went undetected"
    );
}

#[test]
fn seeded_transitive_violations_are_caught() {
    // Each seed keeps the effect at least one call away from the root
    // fn, so the local rules are structurally unable to see it — only
    // the call-graph taint connects root to effect.  Every finding must
    // carry a non-empty witness chain.

    // transitive-wall-clock: a metrics exporter reaching Instant::now
    // through a helper that lives in a wall-clock-allowlisted file.
    let root = "pub fn export_all() -> u64 {\n    stamp()\n}\n";
    let leaf = "pub fn stamp() -> u64 {\n    \
                let t = std::time::Instant::now();\n    \
                t.elapsed().as_nanos() as u64\n}\n";
    let out = lint_sources(&[
        ("rust/src/metrics/mod.rs", root),
        ("rust/src/runtime/executor.rs", leaf),
    ]);
    let hit = out
        .diagnostics
        .iter()
        .find(|d| d.rule == Rule::TransitiveWallClock)
        .expect("seeded transitive wall-clock went undetected");
    assert!(!hit.witness.is_empty(), "finding carries no witness chain");
    assert!(
        out.diagnostics.iter().all(|d| d.rule != Rule::WallClockInSim),
        "the local rule should be silent here: {:#?}",
        out.diagnostics
    );

    // panic-reachability: a pub fl entry point whose unwrap sits in
    // data/, outside unwrap-in-library's scope.
    let api = "pub fn shard_mean(v: &[f32]) -> f32 {\n    head(v)\n}\n";
    let helper = "pub fn head(v: &[f32]) -> f32 {\n    *v.first().unwrap()\n}\n";
    let out = lint_sources(&[
        ("rust/src/fl/api.rs", api),
        ("rust/src/data/shard.rs", helper),
    ]);
    let hit = out
        .diagnostics
        .iter()
        .find(|d| d.rule == Rule::PanicReachability)
        .expect("seeded transitive panic went undetected");
    assert!(!hit.witness.is_empty(), "finding carries no witness chain");
    assert!(
        out.diagnostics.iter().all(|d| d.rule != Rule::UnwrapInLibrary),
        "the local rule should be silent here: {:#?}",
        out.diagnostics
    );

    // pure-local-update: a handle impl reaching entropy via a helper.
    let noisy = "pub trait LocalUpdateHandle {\n    fn run(&self) -> u32;\n}\n\
                 pub struct Noisy;\n\
                 impl LocalUpdateHandle for Noisy {\n    fn run(&self) -> u32 {\n        \
                 entropy()\n    }\n}\n\
                 fn entropy() -> u32 {\n    \
                 let s = std::collections::hash_map::RandomState::new();\n    \
                 probe(&s)\n}\n\
                 fn probe(_s: &std::collections::hash_map::RandomState) -> u32 {\n    0\n}\n";
    let out = lint_sources(&[("rust/src/runtime/native_update.rs", noisy)]);
    let hit = out
        .diagnostics
        .iter()
        .find(|d| d.rule == Rule::PureLocalUpdate)
        .expect("seeded impure local update went undetected");
    assert!(!hit.witness.is_empty(), "finding carries no witness chain");
}

#[test]
fn tree_effects_artifact_is_populated() {
    // The interprocedural pass over the real tree must produce a
    // non-trivial effect table, and calls it cannot resolve (std sinks
    // like Instant::now) are recorded rather than silently dropped.
    let report = lint_tree(repo_root()).expect("tree scan failed");
    assert!(!report.effects.fns.is_empty(), "empty effect table");
    assert!(
        !report.effects.unresolved.is_empty(),
        "expected unresolved std calls in the audit trail"
    );
    let json = report.effects.render_json();
    assert!(json.starts_with("{\n  \"version\": 1"), "artifact schema drifted");
}
