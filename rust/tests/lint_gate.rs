//! Tier-1 gate for the determinism lint: plain `cargo test -q` runs
//! the full-tree `edgeflow-lint` sweep, so a contract violation fails
//! the build even without the dedicated CI job.
//!
//! Exit-code contract of the `edgeflow-lint` binary (the library API
//! used here returns the same diagnostics): 0 = clean, 1 = violations
//! (printed as `file:line:rule: message`), 2 = usage/I-O error.

use std::path::Path;

use edgeflow_lint::{lint_source, lint_tree, Rule};

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is rust/; the repo root is its parent.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ sits inside the repo root")
}

#[test]
fn repo_tree_is_lint_clean() {
    let report = lint_tree(repo_root()).expect("tree scan failed");
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        report.clean(),
        "determinism-lint violations (fix or add a justified \
         lint:allow pragma):\n{}",
        rendered.join("\n")
    );
    // Sanity: the sweep actually visited the tree (src + tests +
    // benches + examples + the lint's own sources).
    assert!(
        report.files_scanned >= 30,
        "scan looks truncated: only {} files visited",
        report.files_scanned
    );
}

#[test]
fn every_suppression_in_tree_carries_a_reason() {
    // Unjustified pragmas surface as `pragma` diagnostics, so a clean
    // tree implies every suppression is explained.  Check the count
    // is nonzero: the fl/runtime unwrap sweep is expected to rely on
    // justified pragmas, and this guards against the engine silently
    // ignoring them.
    let report = lint_tree(repo_root()).expect("tree scan failed");
    assert!(
        report.suppressed > 0,
        "expected at least one justified suppression in the tree"
    );
}

#[test]
fn seeded_violation_is_caught() {
    // A NaN-unsound ordering smuggled into an aggregation module must
    // produce a diagnostic — this is the regression test that the
    // gate actually gates.
    let bad = "pub fn sel(v: &mut Vec<f32>) {\n    \
               v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let out = lint_source("rust/src/fl/aggregate.rs", bad);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::FloatOrdering),
        "seeded partial_cmp went undetected"
    );

    let clock = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    let out = lint_source("rust/src/netsim/sim.rs", clock);
    assert!(
        out.diagnostics.iter().any(|d| d.rule == Rule::WallClockInSim),
        "seeded wall-clock read went undetected"
    );
}
