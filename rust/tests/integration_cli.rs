//! CLI integration tests: spawn the real `edgeflow` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_edgeflow"))
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("--help").output().unwrap();
    let text = String::from_utf8_lossy(&out.stderr);
    for cmd in ["train", "table1", "fig3", "comm-sim", "theory", "inspect"] {
        assert!(text.contains(cmd), "help missing {cmd}: {text}");
    }
}

#[test]
fn unknown_command_exits_2() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn unknown_flag_exits_2() {
    let out = bin().args(["train", "--warp-speed", "9"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn train_help_lists_new_knobs() {
    let out = bin().args(["train", "--help"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--deadline-s"), "{text}");
    assert!(text.contains("edgeflow_latency"), "{text}");
    assert!(text.contains("--straggler-policy"), "{text}");
    assert!(text.contains("--checkpoint-every"), "{text}");
    assert!(text.contains("--resume"), "{text}");
    assert!(text.contains("--engine"), "{text}");
    assert!(text.contains("--codec"), "{text}");
    assert!(text.contains("--checkpoint-keep"), "{text}");
    assert!(text.contains("--resume-latest"), "{text}");
    assert!(text.contains("--adaptive-deadline"), "{text}");
}

#[test]
fn train_rejects_unknown_engine() {
    let out = bin().args(["train", "--engine", "tpu"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("engine"), "{text}");
}

/// Shared flags for a CPU-cheap native training run (no artifacts
/// anywhere — this must pass in a bare checkout).
fn native_train_args() -> Vec<&'static str> {
    vec![
        "train",
        "--engine", "native",
        "--optimizer", "momentum",
        "--lr", "0.01",
        "--algorithm", "edgeflow_seq",
        "--clients", "8",
        "--clusters", "2",
        "--rounds", "3",
        "--k", "1",
        "--batch", "16",
        "--samples", "32",
        "--test-samples", "80",
        "--eval-every", "0",
    ]
}

#[test]
fn train_native_engine_runs_without_artifacts() {
    let csv = std::env::temp_dir().join("edgeflow_cli_native.csv");
    let json = std::env::temp_dir().join("edgeflow_cli_native.json");
    let mut args: Vec<&str> = native_train_args();
    let (csv_s, json_s) = (csv.to_str().unwrap(), json.to_str().unwrap());
    args.extend(["--codec", "int8", "--out", csv_s, "--out-json", json_s]);
    let out = bin().args(&args).output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("final acc"), "{text}");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), 4, "header + 3 rounds: {csv_text}");
    let json_text = std::fs::read_to_string(&json).unwrap();
    assert!(json_text.contains("train_loss"), "{json_text}");
    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&json);
}

#[test]
fn train_native_checkpoint_rotation_and_resume_latest() {
    let dir = std::env::temp_dir().join("edgeflow_cli_ckpt_rotation");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("run.ckpt.json");
    let mut args: Vec<&str> = native_train_args();
    let base_s = base.to_str().unwrap();
    args.extend([
        "--checkpoint-every", "1",
        "--checkpoint", base_s,
        "--checkpoint-keep", "2",
    ]);
    let out = bin().args(&args).output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // 3 rounds checkpointed every round, rotated down to the 2 newest.
    let mut files: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    files.sort();
    assert_eq!(
        files,
        vec!["run.r000002.ckpt.json", "run.r000003.ckpt.json"],
        "rotation keeps the 2 newest round stamps"
    );

    // --resume-latest picks run.r000003 (the finished session) and
    // reports without retraining; no artifacts needed for the native
    // checkpoint.
    let out = bin()
        .args(["train", "--resume-latest", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("final acc"), "{text}");

    // Resuming the *mid-run* r000002 checkpoint replays round 2 for
    // real and must land on the same 3-round report.
    let mid = dir.join("run.r000002.ckpt.json");
    let out = bin()
        .args(["train", "--resume", mid.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let resumed = String::from_utf8_lossy(&out.stdout);
    let summary = |s: &str| {
        s.lines()
            .find(|l| l.contains("final acc"))
            .map(str::to_string)
            .unwrap_or_default()
    };
    assert_eq!(
        summary(&resumed),
        summary(&text),
        "mid-run replay must reach the finished session's summary"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_rejects_non_finite_adaptive_deadline() {
    // "inf" parses as f64 but must surface as a usage error, not an
    // observer-constructor panic.
    let out = bin()
        .args(["train", "--engine", "native", "--adaptive-deadline", "inf"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("adaptive-deadline"), "{text}");
}

#[test]
fn train_rejects_resume_and_resume_latest_together() {
    let out = bin()
        .args(["train", "--resume", "a.ckpt.json", "--resume-latest", "."])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn fig3_native_engine_regenerates_a_cell_without_artifacts() {
    let csv = std::env::temp_dir().join("edgeflow_cli_fig3_native.csv");
    let out = bin()
        .args([
            "fig3",
            "--engine", "native",
            "--optimizer", "momentum",
            "--lr", "0.01",
            "--batch", "16",
            "--samples", "40",
            "--part", "b",
            "--ks", "1",
            "--rounds", "3",
            "--out", csv.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 3(b)"), "{text}");
    assert!(text.contains("K=1"), "{text}");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.lines().count() > 1, "{csv_text}");
    let _ = std::fs::remove_file(&csv);
}

#[test]
fn train_rejects_bad_straggler_policy() {
    let out = bin()
        .args(["train", "--straggler-policy", "hold"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("straggler"), "{text}");
}

#[test]
fn comm_sim_runs_without_artifacts_via_param_count() {
    // The Fig-4 study is pure coordination: an explicit --param-count
    // must make it runnable with no artifact manifest at all (this is
    // what CI's smoke-metrics job leans on).
    let csv = std::env::temp_dir().join("edgeflow_cli_fig4.csv");
    let json = std::env::temp_dir().join("edgeflow_cli_fig4.json");
    let out = bin()
        .args([
            "comm-sim",
            "--param-count", "50000",
            "--rounds", "8",
            "--clusters", "4",
            "--cluster-size", "4",
            "--latency",
            "--out", csv.to_str().unwrap(),
            "--out-json", json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 4"));
    assert!(text.contains("mean transfer latency"));
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.lines().count() > 1, "{csv_text}");
    let json_text = std::fs::read_to_string(&json).unwrap();
    assert!(json_text.contains("byte_hops_per_round"), "{json_text}");
}

#[test]
fn train_resume_rejects_missing_checkpoint() {
    let out = bin()
        .args(["train", "--resume", "/nonexistent/ck.json"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
}

#[test]
fn train_rejects_negative_deadline() {
    let out = bin().args(["train", "--deadline-s", "-2"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("deadline_s"), "{text}");
}

#[test]
fn presets_print() {
    let out = bin().arg("presets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("table1_cifar_niid_b"));
    assert!(text.contains("edgeflow_seq"));
}

#[test]
fn theory_reports_terms_and_kscan() {
    let out = bin()
        .args(["theory", "--eta", "0.02", "--g2", "5", "--kmax", "10"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Theorem 1"));
    assert!(text.contains("K-scan"));
    assert!(text.contains("<-- min"));
}

#[test]
fn theory_rejects_bad_step_size() {
    // LK eta >= 1 violates the theorem hypothesis: the binary must fail,
    // not print garbage.
    let out = bin().args(["theory", "--eta", "0.5", "--k", "5"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn inspect_topology_prints_all_four() {
    let out = bin().args(["inspect", "--topology"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for t in ["simple", "breadth_parallel", "depth_linear", "hybrid"] {
        assert!(text.contains(t), "{t} missing");
    }
}

#[test]
fn inspect_partitions_shows_histograms() {
    let out = bin()
        .args(["inspect", "--partitions", "--clients", "20", "--clusters", "4"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert_eq!(
        text.lines().filter(|l| l.trim_start().starts_with("client ")).count(),
        20
    );
    // labels must match the actual per-client assignment (histogram
    // concentration implies a non-IID label and vice versa)
    for line in text.lines().filter(|l| l.trim_start().starts_with("client ")) {
        let concentrated = line
            .split('[')
            .nth(1)
            .unwrap()
            .split(']')
            .next()
            .unwrap()
            .split_whitespace()
            .any(|n| n.parse::<usize>().unwrap() > 50);
        let labeled_noniid = line.contains("noniid");
        assert_eq!(concentrated, labeled_noniid, "label mismatch: {line}");
    }
}

#[test]
fn inspect_requires_a_mode() {
    let out = bin().arg("inspect").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn train_tiny_run_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let tmp = std::env::temp_dir().join("edgeflow_cli_train.csv");
    let out = bin()
        .args([
            "train",
            "--rounds", "3",
            "--clusters", "4",
            "--k", "2",
            "--samples", "80",
            "--test-samples", "100",
            "--eval-every", "0",
            "--algorithm", "edgeflow_seq",
            "--out", tmp.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("final acc"));
    let csv = std::fs::read_to_string(&tmp).unwrap();
    assert_eq!(csv.lines().count(), 4); // header + 3 rounds
}

#[test]
fn comm_sim_reports_compression_ratios() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let out = bin()
        .args(["comm-sim", "--rounds", "20", "--latency"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 4"));
    assert!(text.contains("depth_linear"));
    assert!(text.contains("mean transfer latency"));
}

#[test]
fn train_rejects_missing_artifact_k() {
    if !have_artifacts() {
        return;
    }
    let out = bin().args(["train", "--rounds", "1", "--k", "7"]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("BUILD_MATRIX") || text.contains("no artifact"), "{text}");
}
