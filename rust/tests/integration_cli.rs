//! CLI integration tests: spawn the real `edgeflow` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_edgeflow"))
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("--help").output().unwrap();
    let text = String::from_utf8_lossy(&out.stderr);
    for cmd in ["train", "table1", "fig3", "comm-sim", "theory", "inspect"] {
        assert!(text.contains(cmd), "help missing {cmd}: {text}");
    }
}

#[test]
fn unknown_command_exits_2() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn unknown_flag_exits_2() {
    let out = bin().args(["train", "--warp-speed", "9"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn train_help_lists_new_knobs() {
    let out = bin().args(["train", "--help"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--deadline-s"), "{text}");
    assert!(text.contains("edgeflow_latency"), "{text}");
    assert!(text.contains("--straggler-policy"), "{text}");
    assert!(text.contains("--checkpoint-every"), "{text}");
    assert!(text.contains("--resume"), "{text}");
}

#[test]
fn train_rejects_bad_straggler_policy() {
    let out = bin()
        .args(["train", "--straggler-policy", "hold"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("straggler"), "{text}");
}

#[test]
fn comm_sim_runs_without_artifacts_via_param_count() {
    // The Fig-4 study is pure coordination: an explicit --param-count
    // must make it runnable with no artifact manifest at all (this is
    // what CI's smoke-metrics job leans on).
    let csv = std::env::temp_dir().join("edgeflow_cli_fig4.csv");
    let json = std::env::temp_dir().join("edgeflow_cli_fig4.json");
    let out = bin()
        .args([
            "comm-sim",
            "--param-count", "50000",
            "--rounds", "8",
            "--clusters", "4",
            "--cluster-size", "4",
            "--latency",
            "--out", csv.to_str().unwrap(),
            "--out-json", json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 4"));
    assert!(text.contains("mean transfer latency"));
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.lines().count() > 1, "{csv_text}");
    let json_text = std::fs::read_to_string(&json).unwrap();
    assert!(json_text.contains("byte_hops_per_round"), "{json_text}");
}

#[test]
fn train_resume_rejects_missing_checkpoint() {
    let out = bin()
        .args(["train", "--resume", "/nonexistent/ck.json"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
}

#[test]
fn train_rejects_negative_deadline() {
    let out = bin().args(["train", "--deadline-s", "-2"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("deadline_s"), "{text}");
}

#[test]
fn presets_print() {
    let out = bin().arg("presets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("table1_cifar_niid_b"));
    assert!(text.contains("edgeflow_seq"));
}

#[test]
fn theory_reports_terms_and_kscan() {
    let out = bin()
        .args(["theory", "--eta", "0.02", "--g2", "5", "--kmax", "10"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Theorem 1"));
    assert!(text.contains("K-scan"));
    assert!(text.contains("<-- min"));
}

#[test]
fn theory_rejects_bad_step_size() {
    // LK eta >= 1 violates the theorem hypothesis: the binary must fail,
    // not print garbage.
    let out = bin().args(["theory", "--eta", "0.5", "--k", "5"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn inspect_topology_prints_all_four() {
    let out = bin().args(["inspect", "--topology"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for t in ["simple", "breadth_parallel", "depth_linear", "hybrid"] {
        assert!(text.contains(t), "{t} missing");
    }
}

#[test]
fn inspect_partitions_shows_histograms() {
    let out = bin()
        .args(["inspect", "--partitions", "--clients", "20", "--clusters", "4"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert_eq!(
        text.lines().filter(|l| l.trim_start().starts_with("client ")).count(),
        20
    );
    // labels must match the actual per-client assignment (histogram
    // concentration implies a non-IID label and vice versa)
    for line in text.lines().filter(|l| l.trim_start().starts_with("client ")) {
        let concentrated = line
            .split('[')
            .nth(1)
            .unwrap()
            .split(']')
            .next()
            .unwrap()
            .split_whitespace()
            .any(|n| n.parse::<usize>().unwrap() > 50);
        let labeled_noniid = line.contains("noniid");
        assert_eq!(concentrated, labeled_noniid, "label mismatch: {line}");
    }
}

#[test]
fn inspect_requires_a_mode() {
    let out = bin().arg("inspect").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn train_tiny_run_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let tmp = std::env::temp_dir().join("edgeflow_cli_train.csv");
    let out = bin()
        .args([
            "train",
            "--rounds", "3",
            "--clusters", "4",
            "--k", "2",
            "--samples", "80",
            "--test-samples", "100",
            "--eval-every", "0",
            "--algorithm", "edgeflow_seq",
            "--out", tmp.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("final acc"));
    let csv = std::fs::read_to_string(&tmp).unwrap();
    assert_eq!(csv.lines().count(), 4); // header + 3 rounds
}

#[test]
fn comm_sim_reports_compression_ratios() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let out = bin()
        .args(["comm-sim", "--rounds", "20", "--latency"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Fig 4"));
    assert!(text.contains("depth_linear"));
    assert!(text.contains("mean transfer latency"));
}

#[test]
fn train_rejects_missing_artifact_k() {
    if !have_artifacts() {
        return;
    }
    let out = bin().args(["train", "--rounds", "1", "--k", "7"]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("BUILD_MATRIX") || text.contains("no artifact"), "{text}");
}
