"""AOT pipeline tests: HLO text validity, manifest/blob consistency,
and an end-to-end lowered-vs-eager numerical check."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

needs_artifacts = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first"
)


def _nelems(entries):
    return sum(int(np.prod(e["shape"])) if e["shape"] else 1 for e in entries)


def test_hlo_text_roundtrip_small():
    """Lowered HLO text must parse back through xla_client (the same
    parser family the Rust xla crate uses)."""
    spec = M.VARIANTS["fashion_mlp"]
    text = aot.lower_eval(spec, 8)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # All parameters appear: params ++ bn ++ x ++ y
    n_inputs = len(M.param_entries(spec)) + len(M.bn_entries(spec)) + 2
    for i in range(n_inputs):
        assert f"parameter({i})" in text, f"missing parameter({i})"


def test_lowered_local_update_matches_eager():
    """The exact artifact computation (lowered) == eager execution."""
    spec = dataclasses.replace(M.VARIANTS["fashion_mlp"], use_pallas=True)
    k, b = 2, 8
    params, bn, opt = M.init_state(spec, "sgd", 0)
    rng = np.random.default_rng(0)
    h, w, c = spec.image
    xs = jnp.asarray(rng.random((k, b, h, w, c)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, (k, b)), jnp.int32)

    def fn(params, bn, opt_state, xs, ys, lr):
        p, s, o, loss = M.local_update_value_and_grad(
            spec, "sgd", params, bn, opt_state, xs, ys, lr
        )
        return tuple(p) + tuple(s) + tuple(o) + (loss,)

    eager = fn(params, bn, opt, xs, ys, jnp.float32(0.01))
    compiled = jax.jit(fn)(params, bn, opt, xs, ys, jnp.float32(0.01))
    for i, (a, b_) in enumerate(zip(eager, compiled)):
        assert_allclose(a, b_, rtol=1e-5, atol=1e-6, err_msg=f"output {i}")


def test_init_blob_deterministic():
    spec = M.VARIANTS["fashion_mlp"]
    assert aot.init_blob(spec, "sgd", 0) == aot.init_blob(spec, "sgd", 0)
    assert aot.init_blob(spec, "sgd", 0) != aot.init_blob(spec, "sgd", 1)


def test_init_blob_length_matches_entries():
    for name in ("fashion_mlp", "fashion_cnn_slim"):
        spec = M.VARIANTS[name]
        for opt in ("sgd", "adam"):
            n = (
                sum(int(np.prod(s)) for _, s in M.param_entries(spec))
                + sum(int(np.prod(s)) for _, s in M.bn_entries(spec))
                + sum(
                    int(np.prod(s)) if s else 1
                    for _, s in M.opt_entries(spec, opt)
                )
            )
            assert len(aot.init_blob(spec, opt, 0)) == 4 * n


def test_backend_actually_differs_between_twin_variants():
    """Regression guard: the *_fast / *_jnp twins must NOT silently lower
    through the Pallas path (an early aot.py bug force-overrode
    use_pallas for every variant)."""
    pallas_spec = M.VARIANTS["fashion_cnn_slim"]
    fast_spec = M.VARIANTS["fashion_cnn_slim_fast"]
    assert pallas_spec.use_pallas and not fast_spec.use_pallas
    t_pallas = aot.lower_eval(pallas_spec, 4)
    t_fast = aot.lower_eval(fast_spec, 4)
    assert t_pallas != t_fast
    # im2col variant lowers the conv to dot ops, no conv instructions
    assert "convolution" not in t_fast
    jnp_spec = M.VARIANTS["fashion_cnn_slim_jnp"]
    t_lax = aot.lower_eval(jnp_spec, 4)
    assert "convolution" in t_lax


@needs_artifacts
def test_manifest_built_with_per_variant_backends():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    v = man["variants"]
    if "fashion_cnn_slim_fast" in v:
        assert v["fashion_cnn_slim_fast"]["backend"] == "jnp/im2col"
        assert v["fashion_cnn_slim"]["backend"] == "pallas"


@needs_artifacts
def test_manifest_structure():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for name, v in man["variants"].items():
        spec = M.VARIANTS[name]
        assert v["arch"] == spec.arch
        assert tuple(v["image"]) == spec.image
        assert [e["name"] for e in v["params"]] == [
            n for n, _ in M.param_entries(spec)
        ]
        for opt in v["optimizers"]:
            assert opt in v["opt_state"]
            assert opt in v["executables"]["local_update"]


@needs_artifacts
def test_manifest_files_exist_with_expected_sizes():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, v in man["variants"].items():
        for opt, blob in v["init_blob"].items():
            path = os.path.join(ART, blob)
            assert os.path.exists(path), blob
            expect = 4 * (
                _nelems(v["params"])
                + _nelems(v["bn_state"])
                + _nelems(v["opt_state"][opt])
            )
            assert os.path.getsize(path) == expect, blob
        epath = os.path.join(ART, v["executables"]["eval"])
        assert os.path.exists(epath)
        for opt, table in v["executables"]["local_update"].items():
            for key, fn in table.items():
                assert os.path.exists(os.path.join(ART, fn)), fn


@needs_artifacts
def test_artifact_hlo_entry_signature():
    """Eval artifact entry computation must declare params+bn+2 inputs."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    v = man["variants"]["fashion_mlp"]
    with open(os.path.join(ART, v["executables"]["eval"])) as f:
        text = f.read()
    n_inputs = len(v["params"]) + len(v["bn_state"]) + 2
    for i in range(n_inputs):
        assert f"parameter({i})" in text
