"""L1 kernel-vs-oracle tests — the core correctness signal.

Every Pallas kernel is asserted against the pure-jnp reference in
``compile.kernels.ref`` with ``assert_allclose``; hypothesis sweeps the
shape space (including non-multiples of the block sizes, degenerate dims,
and the exact shapes the EdgeFLow CNN uses).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    pallas_bn_scale_relu,
    pallas_conv2d_3x3_same,
    pallas_matmul,
    pallas_softmax_xent,
)
from compile.kernels import ref
from compile.kernels.conv2d import im2col_3x3_same
from compile.kernels.matmul import _pick_block

SETTINGS = dict(max_examples=25, deadline=None)


def _randn(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _randn(rng, (m, k)), _randn(rng, (k, n))
    assert_allclose(pallas_matmul(a, b), ref.ref_matmul(a, b), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_grads_match_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _randn(rng, (m, k)), _randn(rng, (k, n))
    ga, gb = jax.grad(lambda a, b: (pallas_matmul(a, b) ** 2).sum(), (0, 1))(a, b)
    ra, rb = jax.grad(lambda a, b: (ref.ref_matmul(a, b) ** 2).sum(), (0, 1))(a, b)
    assert_allclose(ga, ra, rtol=1e-3, atol=1e-3)
    assert_allclose(gb, rb, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("mkn", [(1, 1, 1), (128, 128, 128), (129, 127, 1),
                                 (50176, 9, 8), (64, 576, 64)])
def test_matmul_block_edges(mkn):
    """Exact block multiples, off-by-one, and the CNN im2col shapes."""
    m, k, n = mkn
    rng = np.random.default_rng(7)
    a, b = _randn(rng, (m, k)), _randn(rng, (k, n))
    assert_allclose(pallas_matmul(a, b), ref.ref_matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_fp32_accumulation_is_stable():
    """Large-K contraction should not drift vs fp32 reference."""
    rng = np.random.default_rng(3)
    a, b = _randn(rng, (16, 4096)), _randn(rng, (4096, 16))
    assert_allclose(pallas_matmul(a, b), ref.ref_matmul(a, b), rtol=1e-3, atol=1e-2)


def test_pick_block_shrinks_for_small_dims():
    assert _pick_block(1, 128) == 8
    assert _pick_block(10, 128) == 16
    assert _pick_block(128, 128) == 128
    assert _pick_block(1000, 128) == 128


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    h=st.integers(1, 16),
    w=st.integers(1, 16),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, h, w, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = _randn(rng, (n, h, w, cin))
    f = _randn(rng, (3, 3, cin, cout))
    assert_allclose(
        pallas_conv2d_3x3_same(x, f), ref.ref_conv2d_3x3_same(x, f),
        rtol=1e-4, atol=1e-4,
    )


def test_conv2d_grads_match_ref():
    rng = np.random.default_rng(11)
    x = _randn(rng, (2, 8, 8, 3))
    f = _randn(rng, (3, 3, 3, 4))
    g1 = jax.grad(lambda x, f: (pallas_conv2d_3x3_same(x, f) ** 2).sum(), (0, 1))(x, f)
    g2 = jax.grad(lambda x, f: (ref.ref_conv2d_3x3_same(x, f) ** 2).sum(), (0, 1))(x, f)
    assert_allclose(g1[0], g2[0], rtol=1e-3, atol=1e-3)
    assert_allclose(g1[1], g2[1], rtol=1e-3, atol=1e-3)


def test_im2col_patch_order_matches_filter_reshape():
    """The (dy, dx, c) patch order must match w.reshape(9*Cin, Cout)."""
    rng = np.random.default_rng(5)
    x = _randn(rng, (1, 4, 4, 2))
    patches = im2col_3x3_same(x)
    assert patches.shape == (1, 4, 4, 18)
    # center pixel of patch (dy=1, dx=1) is x itself
    center = patches[0, :, :, 2 * (1 * 3 + 1) : 2 * (1 * 3 + 1) + 2]
    assert_allclose(center, x[0])


def test_conv2d_paper_shapes():
    """The exact first-layer shapes for both datasets."""
    rng = np.random.default_rng(9)
    for hwc, cout in [((28, 28, 1), 16), ((32, 32, 3), 16)]:
        x = _randn(rng, (2, *hwc))
        f = _randn(rng, (3, 3, hwc[2], cout))
        assert_allclose(
            pallas_conv2d_3x3_same(x, f), ref.ref_conv2d_3x3_same(x, f),
            rtol=1e-4, atol=1e-4,
        )


# ---------------------------------------------------------------------------
# fused batchnorm + relu
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    c=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_bn_relu_matches_ref_2d(rows, c, seed):
    rng = np.random.default_rng(seed)
    x = _randn(rng, (rows, c))
    gamma, beta = _randn(rng, (c,)), _randn(rng, (c,))
    mean = jnp.asarray(rng.standard_normal(c), jnp.float32)
    var = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    assert_allclose(
        pallas_bn_scale_relu(x, gamma, beta, mean, var),
        ref.ref_bn_scale_relu(x, gamma, beta, mean, var),
        rtol=1e-4, atol=1e-4,
    )


def test_bn_relu_4d_shape_and_grads():
    rng = np.random.default_rng(13)
    x = _randn(rng, (4, 7, 7, 6))
    gamma, beta = _randn(rng, (6,)), _randn(rng, (6,))

    def f_pallas(x, g, b):
        m, v = ref.ref_batch_stats(x)
        return (pallas_bn_scale_relu(x, g, b, m, v) ** 2).sum()

    def f_ref(x, g, b):
        m, v = ref.ref_batch_stats(x)
        return (ref.ref_bn_scale_relu(x, g, b, m, v) ** 2).sum()

    for i, (a, r) in enumerate(
        zip(jax.grad(f_pallas, (0, 1, 2))(x, gamma, beta),
            jax.grad(f_ref, (0, 1, 2))(x, gamma, beta))
    ):
        assert_allclose(a, r, rtol=1e-3, atol=1e-3, err_msg=f"grad arg {i}")


def test_bn_relu_is_nonnegative():
    rng = np.random.default_rng(17)
    x = _randn(rng, (32, 8))
    out = pallas_bn_scale_relu(
        x, jnp.ones(8), jnp.zeros(8), jnp.zeros(8), jnp.ones(8)
    )
    assert float(out.min()) >= 0.0


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 200),
    c=st.integers(2, 16),
    scale=st.floats(0.1, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_xent_matches_ref(b, c, scale, seed):
    rng = np.random.default_rng(seed)
    logits = _randn(rng, (b, c)) * scale  # large logits probe stability
    y = rng.integers(0, c, b)
    onehot = jax.nn.one_hot(y, c, dtype=jnp.float32)
    assert_allclose(
        pallas_softmax_xent(logits, onehot),
        ref.ref_softmax_xent(logits, onehot),
        rtol=1e-4, atol=1e-4,
    )


def test_xent_grad_matches_ref():
    rng = np.random.default_rng(21)
    logits = _randn(rng, (64, 10))
    onehot = jax.nn.one_hot(rng.integers(0, 10, 64), 10, dtype=jnp.float32)
    d1 = jax.grad(lambda z: pallas_softmax_xent(z, onehot).mean())(logits)
    d2 = jax.grad(lambda z: ref.ref_softmax_xent(z, onehot).mean())(logits)
    assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


def test_xent_uniform_logits_is_log_c():
    onehot = jax.nn.one_hot(jnp.arange(10) % 10, 10, dtype=jnp.float32)
    losses = pallas_softmax_xent(jnp.zeros((10, 10), jnp.float32), onehot)
    assert_allclose(losses, np.full(10, np.log(10.0), np.float32), rtol=1e-5)


def test_xent_grad_rows_sum_to_zero():
    """softmax - onehot rows always sum to 0 (mass conservation)."""
    rng = np.random.default_rng(23)
    logits = _randn(rng, (16, 10))
    onehot = jax.nn.one_hot(rng.integers(0, 10, 16), 10, dtype=jnp.float32)
    d = jax.grad(lambda z: pallas_softmax_xent(z, onehot).sum())(logits)
    assert_allclose(d.sum(axis=-1), np.zeros(16, np.float32), atol=1e-5)


# ---------------------------------------------------------------------------
# maxpool / batch-stats oracle helpers (used by L2)
# ---------------------------------------------------------------------------


def test_maxpool_floor_semantics():
    rng = np.random.default_rng(29)
    x = _randn(rng, (1, 7, 7, 2))
    out = ref.ref_maxpool2x2(x)
    assert out.shape == (1, 3, 3, 2)
    assert_allclose(out[0, 0, 0, 0], x[0, :2, :2, 0].max())


def test_batch_stats_match_numpy():
    rng = np.random.default_rng(31)
    x = _randn(rng, (8, 5, 5, 3))
    mean, var = ref.ref_batch_stats(x)
    xn = np.asarray(x).reshape(-1, 3)
    assert_allclose(mean, xn.mean(0), rtol=1e-5, atol=1e-6)
    assert_allclose(var, xn.var(0), rtol=1e-4, atol=1e-5)
