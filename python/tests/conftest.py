"""Shared pytest fixtures for the EdgeFLow python test suite."""

import os
import sys

import numpy as np
import pytest

# Make `compile.*` importable regardless of the pytest invocation cwd.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PYROOT = os.path.dirname(_HERE)
if _PYROOT not in sys.path:
    sys.path.insert(0, _PYROOT)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
