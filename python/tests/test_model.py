"""L2 model tests: shapes, optimizer semantics, backend agreement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

FAST = M.VARIANTS["fashion_mlp"]
CNN = dataclasses.replace(
    M.VARIANTS["fashion_cnn_slim"], use_pallas=False  # jnp backend: fast tests
)


def _batch(rng, spec, b):
    h, w, c = spec.image
    x = jnp.asarray(rng.random((b, h, w, c)), jnp.float32)
    y = jnp.asarray(rng.integers(0, spec.classes, b), jnp.int32)
    return x, y


def _kbatch(rng, spec, k, b):
    h, w, c = spec.image
    xs = jnp.asarray(rng.random((k, b, h, w, c)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, spec.classes, (k, b)), jnp.int32)
    return xs, ys


# ---------------------------------------------------------------------------
# layout / init
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(M.VARIANTS))
def test_init_matches_entries(name):
    spec = M.VARIANTS[name]
    for opt in ("sgd", "adam"):
        params, bn, opt_state = M.init_state(spec, opt, seed=0)
        assert [tuple(p.shape) for p in params] == [s for _, s in M.param_entries(spec)]
        assert [tuple(p.shape) for p in bn] == [s for _, s in M.bn_entries(spec)]
        assert [tuple(p.shape) for p in opt_state] == [
            s for _, s in M.opt_entries(spec, opt)
        ]


def test_init_deterministic_and_seed_sensitive():
    p0, _, _ = M.init_state(FAST, "sgd", seed=0)
    p0b, _, _ = M.init_state(FAST, "sgd", seed=0)
    p1, _, _ = M.init_state(FAST, "sgd", seed=1)
    for a, b in zip(p0, p0b):
        assert_allclose(a, b)
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(p0, p1))


def test_cnn_flatten_dim_fashion_vs_cifar():
    # 28 -> 14 -> 7 -> 3 pools; 32 -> 16 -> 8 -> 4
    f = M.param_entries(M.VARIANTS["fashion_cnn_slim"])
    c = M.param_entries(M.VARIANTS["cifar_cnn_slim"])
    assert dict(f)["fc1_w"][0] == 3 * 3 * 32
    assert dict(c)["fc1_w"][0] == 4 * 4 * 32


def test_adam_state_is_2p_plus_1():
    n = len(M.param_entries(FAST))
    assert len(M.opt_entries(FAST, "adam")) == 2 * n + 1
    assert M.opt_entries(FAST, "adam")[-1][0] == "adam_t"


# ---------------------------------------------------------------------------
# forward / eval
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [FAST, CNN], ids=["mlp", "cnn"])
def test_forward_shapes(spec, rng):
    params, bn, _ = M.init_state(spec, "sgd", 0)
    x, _ = _batch(rng, spec, 4)
    logits, new_bn = M.forward(spec, params, bn, x, train=True)
    assert logits.shape == (4, spec.classes)
    assert len(new_bn) == len(bn)


def test_eval_batch_counts(rng):
    params, bn, _ = M.init_state(FAST, "sgd", 0)
    x, y = _batch(rng, FAST, 32)
    loss_sum, correct = M.eval_batch(FAST, params, bn, x, y)
    assert 0.0 <= float(correct) <= 32.0
    assert float(loss_sum) > 0.0


def test_bn_running_stats_move_in_train_mode(rng):
    params, bn, _ = M.init_state(CNN, "sgd", 0)
    x, _ = _batch(rng, CNN, 8)
    _, new_bn = M.forward(CNN, params, bn, x, train=True)
    moved = sum(float(jnp.abs(a - b).max()) > 1e-7 for a, b in zip(bn, new_bn))
    assert moved > 0
    _, frozen_bn = M.forward(CNN, params, bn, x, train=False)
    for a, b in zip(bn, frozen_bn):
        assert_allclose(a, b)


# ---------------------------------------------------------------------------
# local_update (paper Eq. 2 / Eq. 3 ingredients)
# ---------------------------------------------------------------------------


def test_local_update_lr0_is_noop_on_params(rng):
    params, bn, opt = M.init_state(FAST, "sgd", 0)
    xs, ys = _kbatch(rng, FAST, 3, 16)
    p2, _, _, loss = M.local_update(FAST, "sgd", params, bn, opt, xs, ys, 0.0)
    for a, b in zip(params, p2):
        assert_allclose(a, b)
    assert float(loss) > 0


def test_local_update_reduces_loss_on_repeated_batch(rng):
    """K SGD steps on the same batch must reduce that batch's loss."""
    spec = FAST
    params, bn, opt = M.init_state(spec, "sgd", 0)
    x, y = _batch(rng, spec, 32)
    xs = jnp.stack([x] * 8)
    ys = jnp.stack([y] * 8)
    p2, bn2, _, _ = M.local_update(spec, "sgd", params, bn, opt, xs, ys, 0.05)

    def batch_loss(p, s):
        l, _ = M.loss_and_bn(spec, p, s, x, y)
        return float(l)

    assert batch_loss(p2, bn2) < batch_loss(params, bn)


def test_adam_t_increments_by_k(rng):
    params, bn, opt = M.init_state(FAST, "adam", 0)
    xs, ys = _kbatch(rng, FAST, 5, 8)
    _, _, opt2, _ = M.local_update(FAST, "adam", params, bn, opt, xs, ys, 1e-3)
    assert float(opt2[-1]) == 5.0


def test_value_and_grad_variant_matches_plain(rng):
    params, bn, opt = M.init_state(FAST, "adam", 0)
    xs, ys = _kbatch(rng, FAST, 3, 16)
    out1 = M.local_update(FAST, "adam", params, bn, opt, xs, ys, 1e-3)
    out2 = M.local_update_value_and_grad(FAST, "adam", params, bn, opt, xs, ys, 1e-3)
    for a, b in zip(out1[0], out2[0]):
        assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    assert_allclose(out1[3], out2[3], rtol=1e-5, atol=1e-6)


def test_sgd_single_step_equals_manual_gradient(rng):
    """One K=1 SGD step must equal theta - lr * grad (Eq. 2)."""
    spec = FAST
    params, bn, opt = M.init_state(spec, "sgd", 0)
    x, y = _batch(rng, spec, 16)
    grads, _ = jax.grad(
        lambda p, s: M.loss_and_bn(spec, p, s, x, y), has_aux=True
    )(params, bn)
    lr = 0.1
    p2, _, _, _ = M.local_update(
        spec, "sgd", params, bn, opt, x[None], y[None], lr
    )
    for p, g, pn in zip(params, grads, p2):
        assert_allclose(pn, p - lr * g, rtol=1e-5, atol=1e-6)


def test_pallas_and_jnp_models_agree(rng):
    """Full-model agreement between the two kernel backends."""
    sp = dataclasses.replace(M.VARIANTS["fashion_cnn_slim"], use_pallas=True)
    sj = dataclasses.replace(sp, use_pallas=False)
    params, bn, opt = M.init_state(sp, "sgd", 0)
    xs, ys = _kbatch(rng, sp, 1, 8)
    o1 = M.local_update_value_and_grad(sp, "sgd", params, bn, opt, xs, ys, 0.01)
    o2 = M.local_update_value_and_grad(sj, "sgd", params, bn, opt, xs, ys, 0.01)
    for a, b in zip(o1[0], o2[0]):
        assert_allclose(a, b, rtol=1e-3, atol=1e-4)
    assert_allclose(o1[3], o2[3], rtol=1e-4, atol=1e-5)


def test_im2col_conv_model_matches_lax(rng):
    """The *_fast (im2col+matmul) variants must agree numerically with
    the lax.conv lowering — they share parameter layouts and artifacts
    must be interchangeable."""
    lax_spec = dataclasses.replace(
        M.VARIANTS["fashion_cnn_slim"], use_pallas=False, conv_impl="lax"
    )
    fast_spec = dataclasses.replace(lax_spec, conv_impl="im2col")
    params, bn, opt = M.init_state(lax_spec, "adam", 0)
    xs, ys = _kbatch(rng, lax_spec, 2, 8)
    o1 = M.local_update_value_and_grad(lax_spec, "adam", params, bn, opt, xs, ys, 1e-3)
    o2 = M.local_update_value_and_grad(fast_spec, "adam", params, bn, opt, xs, ys, 1e-3)
    for a, b in zip(o1[0], o2[0]):
        assert_allclose(a, b, rtol=1e-3, atol=1e-4)
    assert_allclose(o1[3], o2[3], rtol=1e-4, atol=1e-5)


def test_cluster_aggregation_matches_eq3(rng):
    """Average of per-client SGD deltas == Eq. 3 aggregate update."""
    spec = FAST
    params, bn, opt = M.init_state(spec, "sgd", 0)
    lr = 0.05
    deltas = []
    for seed in range(3):
        r = np.random.default_rng(seed)
        xs, ys = _kbatch(r, spec, 2, 16)
        p2, _, _, _ = M.local_update(spec, "sgd", params, bn, opt, xs, ys, lr)
        deltas.append([np.asarray(a - b) for a, b in zip(p2, params)])
    agg = [np.mean([d[i] for d in deltas], axis=0) for i in range(len(params))]
    # Eq. 3: theta^{t+1} - theta^t = -(eta/N) sum_n sum_k g — i.e. the mean
    # of the per-client parameter deltas under SGD.  Check it is nonzero and
    # bounded by the max client delta (convexity of the mean).
    for i, a in enumerate(agg):
        stack = np.stack([d[i] for d in deltas])
        assert (a <= stack.max(0) + 1e-7).all() and (a >= stack.min(0) - 1e-7).all()
