"""Layer-2: the EdgeFLow learning model (paper §IV.A) in functional JAX.

Architecture (paper-faithful): a six-layer CNN with 3x3 kernels, batch
normalization after every convolution, 2x2 max-pooling after every second
convolution, and two fully-connected layers ``(128, 10)``, trained with
cross-entropy under SGD (the paper's analysis, Eq. 2) or Adam (the paper's
experiments).  An MLP variant is provided for fast CPU-scale sweeps.

Everything here is *build-time only*: :mod:`compile.aot` lowers
``local_update`` (K local SGD/Adam steps as a ``lax.scan``, Eq. 2) and
``eval_batch`` to HLO text that the Rust coordinator executes via PJRT.
The compute hot spots route through the Layer-1 Pallas kernels; a pure-jnp
backend (``use_pallas=False``) exists for A/B perf comparisons and as a
secondary oracle for the full model.

Parameter / state layout contract (what the Rust side relies on):
  * ``init_state(spec, opt, seed)`` returns ``(params, bn_state, opt_state)``
    — each a *list* of arrays in a fixed, documented order (see
    ``param_names`` etc.); the manifest records names/shapes.
  * ``local_update``  inputs: params ++ bn ++ opt ++ [xs, ys, lr]
                      outputs: params' ++ bn' ++ opt' ++ [mean_loss]
  * ``eval_batch``    inputs: params ++ bn ++ [x, y]
                      outputs: [loss_sum, correct_count]
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    pallas_bn_scale_relu,
    pallas_conv2d_3x3_same,
    pallas_matmul,
    pallas_softmax_xent,
)
from .kernels import ref

BN_MOMENTUM = 0.9
BN_EPS = 1e-5
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


@dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant."""

    name: str
    arch: str  # "cnn6" | "mlp"
    image: Tuple[int, int, int]  # (H, W, C)
    classes: int = 10
    conv_channels: Tuple[int, ...] = (16, 16, 32, 32, 64, 64)
    fc_hidden: int = 128
    mlp_hidden: Tuple[int, ...] = (128, 64)
    use_pallas: bool = True
    # Convolution lowering for the jnp backend: "lax" (lax.conv — optimal
    # on modern XLA) or "im2col" (patches + matmul — 6.3x faster on the
    # xla_extension 0.5.1 CPU runtime the Rust coordinator embeds, whose
    # Eigen conv path predates the thunk runtime; see EXPERIMENTS.md §Perf).
    conv_impl: str = "lax"


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def _he(rng, shape, fan_in):
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def param_entries(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) of all trainable parameters."""
    h, w, c = spec.image
    entries = []
    if spec.arch == "cnn6":
        cin = c
        for i, cout in enumerate(spec.conv_channels):
            entries.append((f"conv{i}_w", (3, 3, cin, cout)))
            entries.append((f"bn{i}_gamma", (cout,)))
            entries.append((f"bn{i}_beta", (cout,)))
            cin = cout
        # three 2x2 pools (after conv 1, 3, 5) with floor semantics
        fh, fw = h, w
        for _ in range(3):
            fh, fw = fh // 2, fw // 2
        flat = fh * fw * spec.conv_channels[-1]
        entries.append(("fc1_w", (flat, spec.fc_hidden)))
        entries.append(("fc1_b", (spec.fc_hidden,)))
        entries.append(("fc2_w", (spec.fc_hidden, spec.classes)))
        entries.append(("fc2_b", (spec.classes,)))
    elif spec.arch == "mlp":
        din = h * w * c
        for i, dh in enumerate(spec.mlp_hidden):
            entries.append((f"fc{i}_w", (din, dh)))
            entries.append((f"fc{i}_b", (dh,)))
            din = dh
        k = len(spec.mlp_hidden)
        entries.append((f"fc{k}_w", (din, spec.classes)))
        entries.append((f"fc{k}_b", (spec.classes,)))
    else:
        raise ValueError(f"unknown arch {spec.arch!r}")
    return entries


def bn_entries(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) of BN running statistics (non-trainable)."""
    if spec.arch != "cnn6":
        return []
    out = []
    for i, cout in enumerate(spec.conv_channels):
        out.append((f"bn{i}_mean", (cout,)))
        out.append((f"bn{i}_var", (cout,)))
    return out


def opt_entries(spec: ModelSpec, opt: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) of optimizer state tensors."""
    if opt == "sgd":
        return []
    if opt == "adam":
        out = []
        for n, s in param_entries(spec):
            out.append((f"adam_m_{n}", s))
        for n, s in param_entries(spec):
            out.append((f"adam_v_{n}", s))
        out.append(("adam_t", ()))
        return out
    raise ValueError(f"unknown optimizer {opt!r}")


def init_state(spec: ModelSpec, opt: str, seed: int = 0):
    """Initial (params, bn_state, opt_state) as lists of jnp arrays."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_entries(spec):
        if name.endswith("_w"):
            fan_in = int(np.prod(shape[:-1]))
            params.append(jnp.asarray(_he(rng, shape, fan_in)))
        elif "gamma" in name:
            params.append(jnp.ones(shape, jnp.float32))
        else:  # beta, biases
            params.append(jnp.zeros(shape, jnp.float32))
    bn_state = []
    for name, shape in bn_entries(spec):
        bn_state.append(
            jnp.ones(shape, jnp.float32)
            if name.endswith("_var")
            else jnp.zeros(shape, jnp.float32)
        )
    opt_state = [jnp.zeros(s, jnp.float32) for _, s in opt_entries(spec, opt)]
    return params, bn_state, opt_state


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _dense(x, w, b, spec: ModelSpec):
    y = pallas_matmul(x, w) if spec.use_pallas else ref.ref_matmul(x, w)
    return y + b


def _conv(x, w, spec: ModelSpec):
    if spec.use_pallas:
        return pallas_conv2d_3x3_same(x, w)
    if spec.conv_impl == "im2col":
        from .kernels.conv2d import im2col_3x3_same

        n, h, wd, cin = x.shape
        cout = w.shape[-1]
        patches = im2col_3x3_same(x).reshape(n * h * wd, 9 * cin)
        out = ref.ref_matmul(patches, w.reshape(9 * cin, cout))
        return out.reshape(n, h, wd, cout)
    return ref.ref_conv2d_3x3_same(x, w)


def _bn_relu(x, gamma, beta, mean, var, spec: ModelSpec):
    if spec.use_pallas:
        return pallas_bn_scale_relu(x, gamma, beta, mean, var, BN_EPS)
    return ref.ref_bn_scale_relu(x, gamma, beta, mean, var, BN_EPS)


def forward(spec: ModelSpec, params, bn_state, x, train: bool):
    """Compute logits.

    Args:
      spec: model variant.
      params: trainable parameter list (order of :func:`param_entries`).
      bn_state: running BN stats list (order of :func:`bn_entries`).
      x: ``[B, H, W, C]`` batch.
      train: batch statistics + running-stat update if True, running
        statistics if False.

    Returns:
      (logits ``[B, classes]``, new_bn_state list)
    """
    if spec.arch == "mlp":
        b = x.shape[0]
        h = x.reshape(b, -1)
        i = 0
        nlayers = len(spec.mlp_hidden) + 1
        for li in range(nlayers):
            w, bia = params[i], params[i + 1]
            i += 2
            h = _dense(h, w, bia, spec)
            if li < nlayers - 1:
                h = jnp.maximum(h, 0.0)
        return h, list(bn_state)

    # cnn6
    new_bn = []
    h = x
    pi = 0
    for i in range(len(spec.conv_channels)):
        w, gamma, beta = params[pi], params[pi + 1], params[pi + 2]
        pi += 3
        run_mean, run_var = bn_state[2 * i], bn_state[2 * i + 1]
        h = _conv(h, w, spec)
        if train:
            mean, var = ref.ref_batch_stats(h)
            new_bn.append(BN_MOMENTUM * run_mean + (1 - BN_MOMENTUM) * mean)
            new_bn.append(BN_MOMENTUM * run_var + (1 - BN_MOMENTUM) * var)
        else:
            mean, var = run_mean, run_var
            new_bn.append(run_mean)
            new_bn.append(run_var)
        h = _bn_relu(h, gamma, beta, mean, var, spec)
        if i % 2 == 1:  # pool after every second conv
            h = ref.ref_maxpool2x2(h)
    b = h.shape[0]
    h = h.reshape(b, -1)
    h = _dense(h, params[pi], params[pi + 1], spec)
    h = jnp.maximum(h, 0.0)
    logits = _dense(h, params[pi + 2], params[pi + 3], spec)
    return logits, new_bn


def loss_and_bn(spec: ModelSpec, params, bn_state, x, y):
    """Mean cross-entropy over the batch (train mode)."""
    logits, new_bn = forward(spec, params, bn_state, x, train=True)
    onehot = jax.nn.one_hot(y, spec.classes, dtype=logits.dtype)
    if spec.use_pallas:
        losses = pallas_softmax_xent(logits, onehot)
    else:
        losses = ref.ref_softmax_xent(logits, onehot)
    return jnp.mean(losses), new_bn


# ---------------------------------------------------------------------------
# Optimizers + local update (paper Eq. 2, K steps)
# ---------------------------------------------------------------------------


def _sgd_step(params, grads, opt_state, lr):
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, opt_state


def _adam_step(params, grads, opt_state, lr):
    n = len(params)
    m, v, t = opt_state[:n], opt_state[n : 2 * n], opt_state[2 * n]
    t = t + 1.0
    new_m = [ADAM_B1 * mi + (1 - ADAM_B1) * g for mi, g in zip(m, grads)]
    new_v = [ADAM_B2 * vi + (1 - ADAM_B2) * g * g for vi, g in zip(v, grads)]
    mhat_scale = 1.0 / (1.0 - ADAM_B1**t)
    vhat_scale = 1.0 / (1.0 - ADAM_B2**t)
    new_params = [
        p - lr * (mi * mhat_scale) / (jnp.sqrt(vi * vhat_scale) + ADAM_EPS)
        for p, mi, vi in zip(params, new_m, new_v)
    ]
    return new_params, new_m + new_v + [t]


def local_update(spec: ModelSpec, opt: str, params, bn_state, opt_state, xs, ys, lr):
    """K local training steps (Eq. 2) as one ``lax.scan``.

    Args:
      params/bn_state/opt_state: lists per the layout contract.
      xs: ``[K, B, H, W, C]`` minibatches (one per local step).
      ys: ``[K, B]`` int32 labels.
      lr: scalar learning rate.

    Returns:
      (params', bn_state', opt_state', mean loss over the K steps)
    """
    grad_fn = jax.grad(
        lambda p, bn, x, y: loss_and_bn(spec, p, bn, x, y), has_aux=True
    )

    def body(carry, batch):
        params, bn_state, opt_state = carry
        x, y = batch
        grads, new_bn = grad_fn(params, bn_state, x, y)
        loss, _ = loss_and_bn(spec, params, bn_state, x, y)
        if opt == "sgd":
            new_params, new_opt = _sgd_step(params, grads, opt_state, lr)
        else:
            new_params, new_opt = _adam_step(params, grads, opt_state, lr)
        return (new_params, new_bn, new_opt), loss

    (params, bn_state, opt_state), losses = jax.lax.scan(
        body, (params, bn_state, opt_state), (xs, ys)
    )
    return params, bn_state, opt_state, jnp.mean(losses)


def local_update_value_and_grad(spec, opt, params, bn_state, opt_state, xs, ys, lr):
    """Same as :func:`local_update` but avoids the double forward.

    ``jax.value_and_grad`` fuses the loss evaluation with the gradient —
    used by the optimized artifacts; kept separate so tests can compare.
    """
    vg = jax.value_and_grad(
        lambda p, bn, x, y: loss_and_bn(spec, p, bn, x, y), has_aux=True
    )

    def body(carry, batch):
        params, bn_state, opt_state = carry
        x, y = batch
        (loss, new_bn), grads = vg(params, bn_state, x, y)
        if opt == "sgd":
            new_params, new_opt = _sgd_step(params, grads, opt_state, lr)
        else:
            new_params, new_opt = _adam_step(params, grads, opt_state, lr)
        return (new_params, new_bn, new_opt), loss

    (params, bn_state, opt_state), losses = jax.lax.scan(
        body, (params, bn_state, opt_state), (xs, ys)
    )
    return params, bn_state, opt_state, jnp.mean(losses)


def eval_batch(spec: ModelSpec, params, bn_state, x, y):
    """Evaluation on one batch with running BN statistics.

    Returns:
      (loss_sum, correct_count) — both f32 scalars so the caller can
      aggregate exactly over uneven final batches.
    """
    logits, _ = forward(spec, params, bn_state, x, train=False)
    onehot = jax.nn.one_hot(y, spec.classes, dtype=logits.dtype)
    if spec.use_pallas:
        losses = pallas_softmax_xent(logits, onehot)
    else:
        losses = ref.ref_softmax_xent(logits, onehot)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == y).astype(jnp.float32))
    return jnp.sum(losses), correct


# ---------------------------------------------------------------------------
# Variant registry (what aot.py builds)
# ---------------------------------------------------------------------------

VARIANTS = {
    "fashion_cnn": ModelSpec(
        name="fashion_cnn", arch="cnn6", image=(28, 28, 1),
        conv_channels=(16, 16, 32, 32, 64, 64), fc_hidden=128,
    ),
    "cifar_cnn": ModelSpec(
        name="cifar_cnn", arch="cnn6", image=(32, 32, 3),
        conv_channels=(16, 16, 32, 32, 64, 64), fc_hidden=128,
    ),
    "fashion_cnn_slim": ModelSpec(
        name="fashion_cnn_slim", arch="cnn6", image=(28, 28, 1),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64,
    ),
    "cifar_cnn_slim": ModelSpec(
        name="cifar_cnn_slim", arch="cnn6", image=(32, 32, 3),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64,
    ),
    # jnp-backend twins: identical parameter layout, XLA-native ops instead
    # of interpret-mode Pallas (which is ~17x slower on the CNN hot path).
    # *_jnp uses lax.conv (the modern-XLA-optimal lowering, kept for the
    # backend ablation); *_fast uses im2col+matmul, 6.3x faster than lax.conv (92x vs interpret) on the Rust
    # side's xla_extension 0.5.1 CPU runtime — the production CPU variant.
    # See EXPERIMENTS.md §Perf for both measurements.
    "fashion_cnn_slim_jnp": ModelSpec(
        name="fashion_cnn_slim_jnp", arch="cnn6", image=(28, 28, 1),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64, use_pallas=False,
    ),
    "cifar_cnn_slim_jnp": ModelSpec(
        name="cifar_cnn_slim_jnp", arch="cnn6", image=(32, 32, 3),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64, use_pallas=False,
    ),
    "fashion_cnn_slim_fast": ModelSpec(
        name="fashion_cnn_slim_fast", arch="cnn6", image=(28, 28, 1),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64, use_pallas=False,
        conv_impl="im2col",
    ),
    "cifar_cnn_slim_fast": ModelSpec(
        name="cifar_cnn_slim_fast", arch="cnn6", image=(32, 32, 3),
        conv_channels=(8, 8, 16, 16, 32, 32), fc_hidden=64, use_pallas=False,
        conv_impl="im2col",
    ),
    "fashion_mlp": ModelSpec(
        name="fashion_mlp", arch="mlp", image=(28, 28, 1), mlp_hidden=(128, 64)
    ),
    "cifar_mlp": ModelSpec(
        name="cifar_mlp", arch="mlp", image=(32, 32, 3), mlp_hidden=(256, 128)
    ),
}
