"""Fused batch-norm (affine) + ReLU Pallas kernel.

The paper's CNN applies BatchNorm after every convolution.  The
normalize-scale-shift-ReLU tail is memory-bound; fusing it into a single
Pallas kernel removes three elementwise round-trips to HBM.  Batch statistics
(mean/var reductions) are computed outside the kernel in jnp — they are
cheap channel reductions XLA handles natively, and keeping them outside lets
autodiff propagate through the statistics for free.

The kernel computes ``relu((x - mean) * rsqrt(var + eps) * gamma + beta)``
over channel-last blocks.  A ``custom_vjp`` supplies the fused backward for
the kernel itself; gradients through mean/var flow via the jnp statistics.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _bn_relu_kernel(x_ref, m_ref, r_ref, g_ref, b_ref, o_ref):
    """o = relu((x - m) * r * g + b); m/r/g/b broadcast over rows."""
    x = x_ref[...]
    z = (x - m_ref[...]) * r_ref[...] * g_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(z, 0.0)


def _bn_relu_raw(x2, mean, rstd, gamma, beta, *, block_rows: int = 256):
    """Apply the fused kernel over a ``[R, C]`` view (rows = N*H*W)."""
    rows, c = x2.shape
    br = min(block_rows, _ceil_to(rows, 8))
    rp = _ceil_to(rows, br)
    x_p = jnp.pad(x2, ((0, rp - rows), (0, 0))) if rp != rows else x2
    row1 = lambda i: (0, 0)
    out = pl.pallas_call(
        _bn_relu_kernel,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), row1),
            pl.BlockSpec((1, c), row1),
            pl.BlockSpec((1, c), row1),
            pl.BlockSpec((1, c), row1),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), x2.dtype),
        interpret=True,
    )(x_p, mean[None, :], rstd[None, :], gamma[None, :], beta[None, :])
    return out[:rows]


@jax.custom_vjp
def _bn_relu(x2, mean, rstd, gamma, beta):
    return _bn_relu_raw(x2, mean, rstd, gamma, beta)


def _bn_relu_fwd(x2, mean, rstd, gamma, beta):
    y = _bn_relu_raw(x2, mean, rstd, gamma, beta)
    return y, (x2, mean, rstd, gamma, beta, y)


def _bn_relu_bwd(res, dy):
    x2, mean, rstd, gamma, beta, y = res
    dz = dy * (y > 0)
    xc = x2 - mean[None, :]
    dx = dz * (rstd * gamma)[None, :]
    dmean = -jnp.sum(dz, axis=0) * rstd * gamma
    drstd = jnp.sum(dz * xc, axis=0) * gamma
    dgamma = jnp.sum(dz * xc, axis=0) * rstd
    dbeta = jnp.sum(dz, axis=0)
    return dx, dmean, drstd, dgamma, dbeta


_bn_relu.defvjp(_bn_relu_fwd, _bn_relu_bwd)


def pallas_bn_scale_relu(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    eps: float = 1e-5,
) -> jax.Array:
    """Fused ``relu(batchnorm(x))`` with given statistics.

    Args:
      x: ``[..., C]`` activations (any leading dims; flattened to rows).
      gamma, beta: ``[C]`` affine parameters.
      mean, var: ``[C]`` statistics (batch stats at train time, running
        stats at eval time — the caller decides).
      eps: numerical floor for the variance.

    Returns:
      same shape as ``x``.
    """
    shape = x.shape
    c = shape[-1]
    x2 = x.reshape(-1, c)
    rstd = jax.lax.rsqrt(var + eps)
    y = _bn_relu(x2, mean, rstd, gamma, beta)
    return y.reshape(shape)
