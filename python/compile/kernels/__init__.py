"""Layer-1 Pallas kernels for EdgeFLow.

All kernels run under ``interpret=True`` so that they lower to plain HLO ops
executable on the CPU PJRT client (real-TPU lowering would emit Mosaic
custom-calls the CPU plugin cannot run).  Each kernel ships with a
``jax.custom_vjp`` so the L2 model can be differentiated; backward passes
reuse the forward kernels where the math allows (matmul) and fall back to
fused jnp expressions for pure elementwise/reduction tails.

Correctness oracle: :mod:`compile.kernels.ref` (pure jnp), enforced by
``python/tests`` with hypothesis shape sweeps.
"""

from .matmul import pallas_matmul  # noqa: F401
from .conv2d import pallas_conv2d_3x3_same  # noqa: F401
from .norm import pallas_bn_scale_relu  # noqa: F401
from .xent import pallas_softmax_xent  # noqa: F401
