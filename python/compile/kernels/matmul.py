"""Tiled Pallas matmul — the FLOP carrier of the EdgeFLow CNN.

The kernel expresses the HBM->VMEM schedule with a 3-D grid (M/bm, N/bn,
K/bk) and a VMEM accumulator scratch buffer, i.e. the classic systolic
"reduction-innermost" tiling a TPU MXU wants.  Block shapes default to
(128, 128, 128): one fp32 accumulator tile plus one A and one B tile is
  128*128*4 * 3 = 192 KiB  of VMEM per grid step,
far under the ~16 MiB VMEM budget, leaving room for double buffering by the
Mosaic pipeliner on real hardware.  Under ``interpret=True`` (mandatory on
CPU PJRT) the same schedule lowers to a plain HLO loop.

Autodiff: ``pallas_matmul`` carries a ``custom_vjp`` whose backward pass is
two more Pallas matmuls (dA = dY @ B^T, dB = A^T @ dY), so the backward
FLOPs run through the same kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that keeps padding waste low."""
    b = preferred
    while b > 8 and b // 2 >= dim:
        b //= 2
    return b


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """One (bm, bn) output tile; grid axis 2 walks the K reduction."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_raw(a: jax.Array, b: jax.Array, *, bm: int, bn: int, bk: int) -> jax.Array:
    """Non-differentiable tiled pallas matmul on padded operands."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)

    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b

    nk = kp // bk
    out = pl.pallas_call(
        partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def pallas_matmul(
    a: jax.Array, b: jax.Array, bm: int = 128, bn: int = 128, bk: int = 128
) -> jax.Array:
    """Differentiable ``a @ b`` via the tiled Pallas kernel.

    Args:
      a: ``[M, K]`` array.
      b: ``[K, N]`` array.
      bm, bn, bk: preferred block sizes (static; shrunk automatically for
        small operands).

    Returns:
      ``[M, N]`` product with fp32 accumulation.
    """
    return _matmul_raw(a, b, bm=bm, bn=bn, bk=bk)


def _mm_fwd(a, b, bm, bn, bk):
    return _matmul_raw(a, b, bm=bm, bn=bn, bk=bk), (a, b)


def _mm_bwd(bm, bn, bk, res, g):
    a, b = res
    da = _matmul_raw(g, b.T, bm=bm, bn=bn, bk=bk)
    db = _matmul_raw(a.T, g, bm=bm, bn=bn, bk=bk)
    return da.astype(a.dtype), db.astype(b.dtype)


pallas_matmul.defvjp(_mm_fwd, _mm_bwd)
