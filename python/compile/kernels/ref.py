"""Pure-jnp oracle for every Pallas kernel (the correctness contract).

``python/tests`` asserts each kernel against these references with
``assert_allclose`` under hypothesis-driven shape/dtype sweeps.  The
references are intentionally the most direct jnp formulation — no tiling,
no padding, no fusion — so a disagreement always implicates the kernel.
"""

import jax
import jax.numpy as jnp


def ref_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain ``a @ b`` with fp32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def ref_conv2d_3x3_same(x: jax.Array, w: jax.Array) -> jax.Array:
    """3x3 SAME conv, NHWC x HWIO -> NHWC, via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def ref_bn_scale_relu(x, gamma, beta, mean, var, eps: float = 1e-5):
    """relu((x - mean) / sqrt(var + eps) * gamma + beta), stats given."""
    y = (x - mean) / jnp.sqrt(var + eps) * gamma + beta
    return jnp.maximum(y, 0.0)


def ref_softmax_xent(logits, onehot):
    """Per-sample -log softmax(logits)[label] from one-hot labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(onehot * logp, axis=-1)


def ref_batch_stats(x):
    """(mean, biased var) over all axes except the channel-last axis."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.mean(jnp.square(x - mean), axis=axes)
    return mean, var


def ref_maxpool2x2(x):
    """2x2 max pooling, stride 2, NHWC; floor semantics on odd dims."""
    n, h, w, c = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2, :]
    x = x.reshape(n, h2, 2, w2, 2, c)
    return jnp.max(x, axis=(2, 4))
