"""3x3 SAME convolution as im2col + the Pallas matmul kernel.

The paper's model is a six-layer 3x3 CNN; on TPU-like hardware the winning
strategy is to turn the convolution into one large matmul so the MXU carries
all FLOPs.  ``im2col`` (patch extraction) is pure data movement and stays in
jnp — it lowers to slices/concat the XLA CPU backend fuses well — while the
``[N*H*W, 9*Cin] @ [9*Cin, Cout]`` contraction goes through
:func:`compile.kernels.matmul.pallas_matmul`, which also provides the
backward pass (d(im2col) transposes back through the jnp gather
automatically under autodiff).
"""

import jax
import jax.numpy as jnp

from .matmul import pallas_matmul


def im2col_3x3_same(x: jax.Array) -> jax.Array:
    """Extract 3x3 SAME patches.

    Args:
      x: ``[N, H, W, C]`` input.

    Returns:
      ``[N, H, W, 9*C]`` patches, ordered (dy, dx, c) row-major — matching
      a ``[3, 3, Cin, Cout]`` filter reshaped to ``[9*Cin, Cout]``.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    return jnp.concatenate(cols, axis=-1)


def pallas_conv2d_3x3_same(x: jax.Array, w: jax.Array) -> jax.Array:
    """3x3 SAME conv, NHWC, stride 1.

    Args:
      x: ``[N, H, W, Cin]``.
      w: ``[3, 3, Cin, Cout]`` filter.

    Returns:
      ``[N, H, W, Cout]``.
    """
    n, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert (kh, kw) == (3, 3) and cin2 == cin, f"bad filter {w.shape} for {x.shape}"
    patches = im2col_3x3_same(x).reshape(n * h * wd, 9 * cin)
    wmat = w.reshape(9 * cin, cout)
    out = pallas_matmul(patches, wmat)
    return out.reshape(n, h, wd, cout)
