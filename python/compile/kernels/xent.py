"""Fused softmax + cross-entropy Pallas kernel.

Computes per-sample ``-log softmax(logits)[label]`` in one pass with the
numerically-stable max-subtracted logsumexp, over row blocks of the
``[B, C]`` logits.  Labels arrive as one-hot ``[B, C]`` float rows (built by
the caller) so the kernel stays pure elementwise+row-reduction — the form a
VPU wants — instead of doing integer gathers.

Backward (``custom_vjp``): ``dlogits = (softmax - onehot) * dloss[:, None]``,
also fused in a Pallas kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _xent_fwd_kernel(z_ref, oh_ref, loss_ref):
    z = z_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    zs = z - zmax
    lse = jnp.log(jnp.sum(jnp.exp(zs), axis=-1, keepdims=True))
    logp = zs - lse
    loss_ref[...] = -jnp.sum(oh_ref[...] * logp, axis=-1, keepdims=True)


def _xent_bwd_kernel(z_ref, oh_ref, dl_ref, dz_ref):
    z = z_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    p = ez / jnp.sum(ez, axis=-1, keepdims=True)
    dz_ref[...] = (p - oh_ref[...]) * dl_ref[...]


def _pad_rows(a, rp):
    r = a.shape[0]
    return jnp.pad(a, ((0, rp - r),) + ((0, 0),) * (a.ndim - 1)) if rp != r else a


def _xent_raw(logits, onehot, *, block_rows: int = 128):
    b, c = logits.shape
    br = min(block_rows, _ceil_to(b, 8))
    bp = _ceil_to(b, br)
    out = pl.pallas_call(
        _xent_fwd_kernel,
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), logits.dtype),
        interpret=True,
    )(_pad_rows(logits, bp), _pad_rows(onehot, bp))
    return out[:b, 0]


def _xent_grad_raw(logits, onehot, dloss, *, block_rows: int = 128):
    b, c = logits.shape
    br = min(block_rows, _ceil_to(b, 8))
    bp = _ceil_to(b, br)
    dz = pl.pallas_call(
        _xent_bwd_kernel,
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), logits.dtype),
        interpret=True,
    )(_pad_rows(logits, bp), _pad_rows(onehot, bp), _pad_rows(dloss[:, None], bp))
    return dz[:b]


@jax.custom_vjp
def pallas_softmax_xent(logits: jax.Array, onehot: jax.Array) -> jax.Array:
    """Per-sample cross-entropy loss.

    Args:
      logits: ``[B, C]`` unnormalized scores.
      onehot: ``[B, C]`` one-hot float labels (not differentiated).

    Returns:
      ``[B]`` losses.
    """
    return _xent_raw(logits, onehot)


def _sx_fwd(logits, onehot):
    return _xent_raw(logits, onehot), (logits, onehot)


def _sx_bwd(res, dloss):
    logits, onehot = res
    dz = _xent_grad_raw(logits, onehot, dloss)
    return dz, jnp.zeros_like(onehot)


pallas_softmax_xent.defvjp(_sx_fwd, _sx_bwd)
