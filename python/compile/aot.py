"""AOT pipeline: lower the L2 model to HLO text + init blobs + manifest.

Runs once at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards.  Interchange format is **HLO text** — the image's
xla_extension 0.5.1 rejects serialized protos from jax>=0.5 (64-bit
instruction ids), while the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs in ``artifacts/``:
  * ``<variant>_<opt>_k<K>_b<B>_local_update.hlo.txt``
  * ``<variant>_eval_b<B>.hlo.txt``
  * ``<variant>_<opt>_init.bin``   — f32 LE blob: params ++ bn ++ opt
  * ``manifest.json``              — shapes, orders, executable table

Usage: ``cd python && python -m compile.aot --out ../artifacts``
(``--fast`` restricts to the MLP variants for quick CI runs;
``--backend jnp`` swaps the Pallas kernels for the jnp oracle — used by the
perf ablation in EXPERIMENTS.md §Perf.)
"""

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Build matrix: variant -> (opts, K values, train batch, eval batch).
# Pallas-kernel variants are the primary artifacts; *_jnp twins (identical
# parameter layout) exist for long CPU runs and the §Perf backend ablation;
# the full-width fashion_cnn/cifar_cnn are "paper-scale" reference builds.
BUILD_MATRIX = {
    "fashion_mlp": (("sgd", "adam"), (1, 2, 5, 10), 64, 100),
    "cifar_mlp": (("adam",), (1, 2, 5, 10), 64, 100),
    "fashion_cnn_slim": (("sgd", "adam"), (5,), 64, 100),
    "cifar_cnn_slim": (("adam",), (5,), 64, 100),
    "fashion_cnn_slim_jnp": (("sgd", "adam"), (5,), 64, 100),
    "cifar_cnn_slim_jnp": (("adam",), (5,), 64, 100),
    "fashion_cnn_slim_fast": (("sgd", "adam"), (5,), 64, 100),
    "cifar_cnn_slim_fast": (("adam",), (1, 2, 5, 10), 64, 100),
    "fashion_cnn": (("adam",), (5,), 64, 100),
    "cifar_cnn": (("adam",), (5,), 64, 100),
}
FAST_VARIANTS = ("fashion_mlp", "cifar_mlp")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_local_update(spec: M.ModelSpec, opt: str, k: int, b: int) -> str:
    """Lower K local steps (scan) to HLO text."""
    h, w, c = spec.image

    def fn(params, bn, opt_state, xs, ys, lr):
        p, s, o, loss = M.local_update_value_and_grad(
            spec, opt, params, bn, opt_state, xs, ys, lr
        )
        return tuple(p) + tuple(s) + tuple(o) + (loss,)

    params = [_sds(s) for _, s in M.param_entries(spec)]
    bn = [_sds(s) for _, s in M.bn_entries(spec)]
    opt_state = [_sds(s) for _, s in M.opt_entries(spec, opt)]
    xs = _sds((k, b, h, w, c))
    ys = _sds((k, b), jnp.int32)
    lr = _sds((), jnp.float32)
    lowered = jax.jit(fn).lower(params, bn, opt_state, xs, ys, lr)
    return to_hlo_text(lowered)


def lower_eval(spec: M.ModelSpec, b: int) -> str:
    """Lower single-batch evaluation to HLO text."""
    h, w, c = spec.image

    def fn(params, bn, x, y):
        return M.eval_batch(spec, params, bn, x, y)

    params = [_sds(s) for _, s in M.param_entries(spec)]
    bn = [_sds(s) for _, s in M.bn_entries(spec)]
    x = _sds((b, h, w, c))
    y = _sds((b,), jnp.int32)
    lowered = jax.jit(fn).lower(params, bn, x, y)
    return to_hlo_text(lowered)


def init_blob(spec: M.ModelSpec, opt: str, seed: int) -> bytes:
    """Little-endian f32 concatenation of params ++ bn ++ opt_state."""
    params, bn, opt_state = M.init_state(spec, opt, seed)
    parts = [np.asarray(a, dtype="<f4").ravel() for a in params + bn + opt_state]
    return np.concatenate(parts).tobytes() if parts else b""


def variant_manifest(spec: M.ModelSpec, opts, ks, b_train, b_eval) -> dict:
    ent = lambda pairs: [{"name": n, "shape": list(s)} for n, s in pairs]
    execs = {
        "eval": f"{spec.name}_eval_b{b_eval}.hlo.txt",
        "local_update": {
            opt: {
                f"k{k}_b{b_train}": f"{spec.name}_{opt}_k{k}_b{b_train}_local_update.hlo.txt"
                for k in ks
            }
            for opt in opts
        },
    }
    return {
        "arch": spec.arch,
        "backend": "pallas" if spec.use_pallas else f"jnp/{spec.conv_impl}",
        "image": list(spec.image),
        "classes": spec.classes,
        "train_batch": b_train,
        "eval_batch": b_eval,
        "k_values": list(ks),
        "optimizers": list(opts),
        "params": ent(M.param_entries(spec)),
        "bn_state": ent(M.bn_entries(spec)),
        "opt_state": {opt: ent(M.opt_entries(spec, opt)) for opt in opts},
        "init_blob": {opt: f"{spec.name}_{opt}_init.bin" for opt in opts},
        "executables": execs,
        "io_contract": {
            "local_update_inputs": "params ++ bn ++ opt ++ [xs(K,B,H,W,C) f32, ys(K,B) i32, lr() f32]",
            "local_update_outputs": "params ++ bn ++ opt ++ [mean_loss() f32]",
            "eval_inputs": "params ++ bn ++ [x(B,H,W,C) f32, y(B) i32]",
            "eval_outputs": "[loss_sum() f32, correct() f32]",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--fast", action="store_true", help="MLP variants only")
    ap.add_argument(
        "--backend", choices=("auto", "pallas", "jnp"), default="auto",
        help="kernel backend lowered into the HLO: auto = per-variant "
        "(the registry's use_pallas flag), pallas/jnp = force override",
    )
    ap.add_argument("--seed", type=int, default=0, help="init parameter seed")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant subset to build"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(BUILD_MATRIX)
    if args.fast:
        names = [n for n in names if n in FAST_VARIANTS]
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    manifest = {"version": 1, "backend": args.backend, "seed": args.seed,
                "variants": {}}
    for name in names:
        opts, ks, b_train, b_eval = BUILD_MATRIX[name]
        spec = M.VARIANTS[name]
        if args.backend != "auto":
            spec = dataclasses.replace(spec, use_pallas=(args.backend == "pallas"))
        print(f"[aot] {name}: opts={opts} ks={ks} b={b_train}", flush=True)
        for opt in opts:
            for k in ks:
                path = f"{name}_{opt}_k{k}_b{b_train}_local_update.hlo.txt"
                text = lower_local_update(spec, opt, k, b_train)
                with open(os.path.join(args.out, path), "w") as f:
                    f.write(text)
                print(f"[aot]   wrote {path} ({len(text)} chars)", flush=True)
            blob = init_blob(spec, opt, args.seed)
            with open(os.path.join(args.out, f"{name}_{opt}_init.bin"), "wb") as f:
                f.write(blob)
        epath = f"{name}_eval_b{b_eval}.hlo.txt"
        with open(os.path.join(args.out, epath), "w") as f:
            f.write(lower_eval(spec, b_eval))
        print(f"[aot]   wrote {epath}", flush=True)
        manifest["variants"][name] = variant_manifest(spec, opts, ks, b_train, b_eval)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] manifest with {len(manifest['variants'])} variants -> "
          f"{args.out}/manifest.json")


if __name__ == "__main__":
    main()
